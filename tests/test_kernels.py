"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(ref.py), executed in interpret mode on CPU (TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quantization as Q
from repro.kernels import ref
from repro.kernels.quant_pack import (delta_quantize_pack,
                                      dequant_unpack_accumulate)

KEY = jax.random.PRNGKey(0)


def _data(r, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    a = jax.random.normal(ks[0], (r, d), jnp.float32).astype(dtype)
    m = (jax.random.normal(ks[1], (r, d), jnp.float32) * 0.1).astype(dtype)
    return a, m


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("r,d", [(8, 128), (128, 256), (256, 512),
                                 (32, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_delta_quantize_pack_matches_ref(bits, r, d, dtype):
    a, m = _data(r, d, dtype)
    packed, scale, m_new = delta_quantize_pack(a, m, bits=bits)
    p_ref, s_ref, m_ref = ref.delta_quantize_pack_ref(a, m, bits)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(p_ref))
    np.testing.assert_allclose(np.asarray(scale), np.asarray(s_ref),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m_new), np.asarray(m_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("r,d", [(8, 128), (64, 640)])
def test_dequant_unpack_accumulate_matches_ref(bits, r, d):
    a, m = _data(r, d, jnp.float32, seed=3)
    packed, scale, _ = delta_quantize_pack(a, m, bits=bits)
    got = dequant_unpack_accumulate(packed, scale, m, bits=bits)
    want = ref.dequant_unpack_accumulate_ref(packed, scale, m, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_sender_receiver_buffer_sync(bits):
    """The algorithmic invariant the kernels must preserve: after one
    exchange, sender's m_new equals receiver's reconstruction exactly
    (Algorithm 2's bit-identical buffer replicas)."""
    a, m = _data(64, 512, jnp.float32, seed=7)
    packed, scale, m_sender = delta_quantize_pack(a, m, bits=bits)
    m_receiver = dequant_unpack_accumulate(packed, scale, m, bits=bits)
    np.testing.assert_array_equal(np.asarray(m_sender),
                                  np.asarray(m_receiver))


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_kernel_consistent_with_core_wire_format(bits):
    """Kernel wire format == core.quantization deterministic wire format
    (so the Pallas path can replace the jnp path transparently)."""
    a, m = _data(16, 256, jnp.float32, seed=11)
    packed, scale, _ = delta_quantize_pack(a, m, bits=bits)
    delta = a - m
    codes, s2 = Q.quantize(delta, bits, stochastic=False)
    np.testing.assert_allclose(np.asarray(scale), np.asarray(s2),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(Q.pack_codes(codes, bits)))


@settings(max_examples=10, deadline=None)
@given(bits=st.sampled_from([2, 4, 8]),
       r=st.sampled_from([4, 32, 128]),
       dscale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 2 ** 31 - 1))
def test_property_roundtrip_error_bounded(bits, r, dscale, seed):
    """|reconstruction - truth| <= one quantization cell, any magnitude."""
    d = 256
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (r, d)) * dscale
    m = jnp.zeros((r, d))
    packed, scale, m_new = delta_quantize_pack(a, m, bits=bits)
    cell = 2.0 * np.asarray(scale) / ((1 << bits) - 1)
    err = np.abs(np.asarray(m_new) - np.asarray(a))
    assert np.all(err <= 0.5 * cell + 1e-6 * dscale)
