"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(ref.py), executed in interpret mode on CPU (TPU is the target).

Hypothesis property tests live in tests/test_properties.py (guarded by
pytest.importorskip so collection succeeds without hypothesis); the
reference-vs-pallas bit-identity contract is tests/test_boundary_parity.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantization as Q
from repro.kernels import ops, ref
from repro.kernels.quant_pack import (delta_quantize_pack,
                                      dequant_sum_mean,
                                      dequant_unpack_accumulate,
                                      pack_sums, quantize_codes_scaled,
                                      quantize_pack, quantize_pack_scaled,
                                      unpack_accumulate, unpack_codes,
                                      unpack_dequant, unpack_sums)

KEY = jax.random.PRNGKey(0)


def _data(r, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    a = jax.random.normal(ks[0], (r, d), jnp.float32).astype(dtype)
    m = (jax.random.normal(ks[1], (r, d), jnp.float32) * 0.1).astype(dtype)
    return a, m


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("r,d", [(8, 128), (128, 256), (256, 512),
                                 (32, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_delta_quantize_pack_matches_ref(bits, r, d, dtype):
    a, m = _data(r, d, dtype)
    packed, scale, m_new = delta_quantize_pack(a, m, bits=bits)
    p_ref, s_ref, m_ref = ref.delta_quantize_pack_ref(a, m, bits)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(p_ref))
    np.testing.assert_allclose(np.asarray(scale), np.asarray(s_ref),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m_new), np.asarray(m_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_delta_quantize_pack_stochastic_matches_ref(bits):
    a, m = _data(64, 256, jnp.float32, seed=5)
    u = jax.random.uniform(KEY, a.shape, jnp.float32)
    packed, scale, m_new = delta_quantize_pack(a, m, u, bits=bits)
    p_ref, s_ref, m_ref = ref.delta_quantize_pack_ref(a, m, bits, u)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(p_ref))
    np.testing.assert_allclose(np.asarray(m_new), np.asarray(m_ref),
                               rtol=1e-5, atol=1e-5)
    # stochastic rounding must actually differ from deterministic
    p_det, _, _ = delta_quantize_pack(a, m, bits=bits)
    assert np.any(np.asarray(packed) != np.asarray(p_det))


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("r,d", [(8, 128), (64, 640)])
def test_dequant_unpack_accumulate_matches_ref(bits, r, d):
    a, m = _data(r, d, jnp.float32, seed=3)
    packed, scale, _ = delta_quantize_pack(a, m, bits=bits)
    got = dequant_unpack_accumulate(packed, scale, m, bits=bits)
    want = ref.dequant_unpack_accumulate_ref(packed, scale, m, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("stochastic", [False, True])
def test_quantize_pack_matches_ref(bits, stochastic):
    x, _ = _data(64, 512, jnp.float32, seed=9)
    u = jax.random.uniform(KEY, x.shape, jnp.float32) if stochastic \
        else None
    packed, scale = quantize_pack(x, u, bits=bits)
    p_ref, s_ref = ref.quantize_pack_ref(x, bits, u)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(p_ref))
    np.testing.assert_allclose(np.asarray(scale), np.asarray(s_ref),
                               rtol=1e-6)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_unpack_dequant_matches_ref(bits):
    x, _ = _data(32, 256, jnp.float32, seed=13)
    packed, scale = quantize_pack(x, bits=bits)
    got = unpack_dequant(packed, scale, bits=bits)
    want = ref.unpack_dequant_ref(packed, scale, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # the round trip is within one quantization cell of the input
    cell = 2.0 * np.asarray(scale) / ((1 << bits) - 1)
    assert np.all(np.abs(np.asarray(got) - np.asarray(x))
                  <= 0.5 * cell + 1e-6)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_sender_receiver_buffer_sync(bits):
    """The algorithmic invariant the kernels must preserve: after one
    exchange, sender's m_new equals receiver's reconstruction exactly
    (Algorithm 2's bit-identical buffer replicas)."""
    a, m = _data(64, 512, jnp.float32, seed=7)
    packed, scale, m_sender = delta_quantize_pack(a, m, bits=bits)
    m_receiver = dequant_unpack_accumulate(packed, scale, m, bits=bits)
    np.testing.assert_array_equal(np.asarray(m_sender),
                                  np.asarray(m_receiver))


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_kernel_consistent_with_core_wire_format(bits):
    """Kernel wire format == core.quantization deterministic wire format
    (so the Pallas path can replace the jnp path transparently)."""
    a, m = _data(16, 256, jnp.float32, seed=11)
    packed, scale, _ = delta_quantize_pack(a, m, bits=bits)
    delta = a - m
    codes, s2 = Q.quantize(delta, bits, stochastic=False)
    np.testing.assert_allclose(np.asarray(scale), np.asarray(s2),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(packed),
                                  np.asarray(Q.pack_codes(codes, bits)))


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("stochastic", [False, True])
def test_quantize_pack_scaled_matches_ref(bits, stochastic):
    """DP gradient-wire sender: quantize against a supplied (shared)
    scale, never a locally computed one."""
    x, _ = _data(64, 512, jnp.float32, seed=21)
    s = 1.3 * jnp.max(jnp.abs(x), axis=-1, keepdims=True)   # pmax-style
    u = jax.random.uniform(KEY, x.shape, jnp.float32) if stochastic \
        else None
    packed = quantize_pack_scaled(x, s, u, bits=bits)
    p_ref = ref.quantize_pack_scaled_ref(x, s, bits, u)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(p_ref))
    # the supplied scale must actually be used: a scaled-up s changes
    # the codes vs the local-absmax kernel
    p_local, _ = quantize_pack(x, u, bits=bits)
    assert np.any(np.asarray(packed) != np.asarray(p_local))


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("r,d", [(8, 128), (64, 640)])
def test_unpack_codes_matches_ref(bits, r, d):
    x, _ = _data(r, d, jnp.float32, seed=23)
    packed, _ = quantize_pack(x, bits=bits)
    got = unpack_codes(packed, bits=bits)
    want = ref.unpack_codes_ref(packed, bits)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("n", [1, 2, 5])
def test_dequant_sum_mean_matches_ref_and_mean_semantics(bits, n):
    """Receiver of the compressed allreduce: the int32 code sum over n
    workers dequantizes to the exact mean of the n dequantized values."""
    s = jnp.maximum(jnp.abs(
        jax.random.normal(jax.random.PRNGKey(29), (32, 1))), 0.1)
    codes = [jax.random.randint(jax.random.PRNGKey(31 + i), (32, 256),
                                0, (1 << bits)).astype(jnp.int32)
             for i in range(n)]
    total = sum(codes)
    got = dequant_sum_mean(total, s, bits=bits, n=n)
    want = ref.dequant_sum_mean_ref(total, s, bits, n)
    # jit-vs-eager may differ by 1 ulp (documented contract); the strict
    # bit-identity gate for the jitted backends is test_grad_compress.py
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)
    per = [ref.dequant_sum_mean_ref(c, s, bits, 1) for c in codes]
    np.testing.assert_allclose(np.asarray(got),
                               np.mean(np.stack(per), axis=0),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("stochastic", [False, True])
@pytest.mark.parametrize("pack", [False, True])
def test_quantize_codes_scaled_matches_ref_and_packed_path(bits, stochastic,
                                                           pack):
    """Codes-only encode (the ring/psum sender): one pass must emit the
    SAME codes the pack→unpack round trip produced, and with pack=True
    the same packed payload as `quantize_pack_scaled` — including an
    all-zero row, whose raw zero scale both backends clamp."""
    x, _ = _data(64, 512, jnp.float32, seed=33)
    x = x.at[7].set(0.0)
    s = jnp.maximum(1.3 * jnp.max(jnp.abs(x), axis=-1, keepdims=True), 0.0)
    u = jax.random.uniform(KEY, x.shape, jnp.float32) if stochastic \
        else None
    out = quantize_codes_scaled(x, s, u, bits=bits, pack=pack)
    want_codes = ref.quantize_codes_scaled_ref(x, s, bits, u)
    if pack:
        packed, codes = out
        np.testing.assert_array_equal(
            np.asarray(packed),
            np.asarray(quantize_pack_scaled(x, s, u, bits=bits)))
    else:
        codes = out
    assert codes.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(codes),
                                  np.asarray(want_codes))
    # identical to the legacy pack -> unpack_codes round trip
    round_trip = unpack_codes(quantize_pack_scaled(x, s, u, bits=bits),
                              bits=bits)
    np.testing.assert_array_equal(np.asarray(codes),
                                  np.asarray(round_trip))


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("r,d", [(8, 128), (64, 640)])
def test_unpack_accumulate_matches_ref(bits, r, d):
    """The ring's fused accumulate step: acc + unpack(packed), int32."""
    x, _ = _data(r, d, jnp.float32, seed=41)
    packed, _ = quantize_pack(x, bits=bits)
    acc = jax.random.randint(jax.random.PRNGKey(43), (r, d), 0,
                             3 * ((1 << bits) - 1)).astype(jnp.int32)
    got = unpack_accumulate(packed, acc, bits=bits)
    want = ref.unpack_accumulate_ref(packed, acc, bits)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # n sequential accumulations == the exact code sum (psum parity)
    total = jnp.zeros((r, d), jnp.int32)
    for _ in range(3):
        total = unpack_accumulate(packed, total, bits=bits)
    np.testing.assert_array_equal(
        np.asarray(total), 3 * np.asarray(unpack_codes(packed, bits=bits)))


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shape", [(13, 256), (37, 128)])
def test_unpack_accumulate_ops_ragged_rows(bits, shape):
    """Ragged (last) ring segments: the ops wrapper zero-pads rows up to
    the block grid; padded rows accumulate zeros and are sliced off."""
    r, d = shape
    x = jax.random.normal(jax.random.PRNGKey(47), shape, jnp.float32)
    packed, _ = ops.quantize_pack(x, bits=bits)
    acc = jax.random.randint(jax.random.PRNGKey(48), shape, 0,
                             (1 << bits)).astype(jnp.int32)
    got = ops.unpack_accumulate(packed, acc, bits=bits)
    want = ref.unpack_accumulate_ref(packed.reshape(r, -1),
                                     acc.reshape(r, d), bits)
    np.testing.assert_array_equal(np.asarray(got).reshape(r, d),
                                  np.asarray(want))


@pytest.mark.parametrize("bits,n", [(2, 3), (2, 8), (4, 2), (4, 8),
                                    (8, 2), (8, 5)])
def test_pack_unpack_sums_roundtrip_and_ref(bits, n):
    """Code-SUM packing (the ring's all-gather payload) at the narrowest
    width holding n*(2**bits - 1): kernel == oracle, and the round trip
    is lossless for every representable sum including the max."""
    lv = (1 << bits) - 1
    total = jax.random.randint(jax.random.PRNGKey(51), (32, 256), 0,
                               n * lv + 1).astype(jnp.int32)
    total = total.at[0, 0].set(n * lv).at[0, 1].set(0)
    got_p = pack_sums(total, bits=bits, n=n)
    want_p = ref.pack_sums_ref(total, bits, n)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))
    assert got_p.shape[-1] == Q.sum_packed_width(256, bits, n)
    back = unpack_sums(got_p, bits=bits, n=n)
    np.testing.assert_array_equal(np.asarray(back)[..., :256],
                                  np.asarray(total))
    np.testing.assert_array_equal(
        np.asarray(ref.unpack_sums_ref(got_p, bits, n))[..., :256],
        np.asarray(total))


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shape", [(13, 256), (3, 67, 128), (200, 512)])
def test_ops_wrappers_handle_ragged_rows(bits, shape):
    """ops.* flatten any (..., d) batch and zero-pad ragged row counts
    up to the kernel's block grid; outputs must match the oracle on the
    live rows exactly."""
    d = shape[-1]
    a = jax.random.normal(jax.random.PRNGKey(17), shape, jnp.float32)
    m = 0.1 * jax.random.normal(jax.random.PRNGKey(18), shape)
    packed, scale, m_new = ops.boundary_compress(a, m, bits=bits)
    p_ref, s_ref, m_ref = ref.delta_quantize_pack_ref(
        a.reshape(-1, d), m.reshape(-1, d), bits)
    np.testing.assert_array_equal(
        np.asarray(packed).reshape(-1, packed.shape[-1]), np.asarray(p_ref))
    np.testing.assert_allclose(
        np.asarray(m_new).reshape(-1, d), np.asarray(m_ref),
        rtol=1e-5, atol=1e-5)
    got = ops.boundary_decompress(packed, scale, m, bits=bits)
    np.testing.assert_array_equal(np.asarray(got).reshape(-1, d),
                                  np.asarray(m_new).reshape(-1, d))
