"""The bit-exactness contract between the reference and Pallas boundary
backends.

Both backends of `repro.core.boundary` must produce IDENTICAL bits —
wire codes, scales, updated messages m_new, and backward gradients —
under jit (the only regime the pipeline ever runs; XLA strength-reduces
constant divisions under jit, so eager reference output may differ by 1
ulp and is not part of the contract).  This is what lets the fused
Pallas kernels replace the jnp chain without changing the trained
model, and what keeps sender/receiver buffer replicas synchronized
across machines running either backend (Algorithm 2).

Sweeps: bits ∈ {2, 4, 8} × {deterministic, stochastic} × {f32, bf16}
buffers × row counts that are odd / ragged vs the kernel block size.

Scope: the contract is per-op — same inputs, same bits.  End-to-end
training trajectories may drift at ulp level across backends because
the opaque pallas_call changes XLA's fusion of SURROUNDING model ops
(verified: boundary outputs bit-equal, stage-interior activations 1-ulp
apart) — that is compiler noise, not a codec divergence, and it is why
these tests pin the boundary ops rather than whole-model runs.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aqsgd
from repro.core import boundary as B
from repro.core.aqsgd import CompressionConfig

BITS = [2, 4, 8]
KEY = jax.random.PRNGKey(0)


def _data(r, d, dtype, scale=0.1):
    a = jax.random.normal(jax.random.PRNGKey(1), (r, d),
                          jnp.float32).astype(dtype)
    m = (scale * jax.random.normal(jax.random.PRNGKey(2), (r, d))
         ).astype(dtype)
    return a, m


@functools.partial(jax.jit, static_argnames=("bits", "stoch", "backend"))
def _enc(a, m, key, *, bits, stoch, backend):
    return B.encode_delta(a, m, bits=bits, stochastic=stoch, key=key,
                          backend=backend)


@functools.partial(jax.jit, static_argnames=("bits", "backend"))
def _dec(packed, scale, m, *, bits, backend):
    return B.decode_accumulate(packed, scale, m, bits=bits,
                               backend=backend)


@functools.partial(jax.jit, static_argnames=("bits", "stoch", "backend"))
def _rt(x, key, *, bits, stoch, backend):
    return B.roundtrip(x, bits=bits, stochastic=stoch, key=key,
                       backend=backend)


def _eq(name, a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                  err_msg=name)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("stoch", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("r", [8, 37, 200])
def test_encode_delta_bit_identical(bits, stoch, dtype, r):
    """Forward wire: packed codes, scales, and m_new all bit-equal."""
    a, m = _data(r, 256, dtype)
    ref = _enc(a, m, KEY, bits=bits, stoch=stoch, backend="reference")
    pal = _enc(a, m, KEY, bits=bits, stoch=stoch, backend="pallas")
    for name, x, y in zip(("packed", "scale", "m_new"), ref, pal):
        _eq(name, x, y)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_accumulate_bit_identical(bits, dtype):
    """Receiver side, and the Algorithm-2 invariant across backends:
    sender m_new == receiver reconstruction, whichever backend ran
    either side."""
    a, m = _data(37, 256, dtype)
    packed, scale, m_new = _enc(a, m, KEY, bits=bits, stoch=False,
                                backend="reference")
    ref = _dec(packed, scale, m, bits=bits, backend="reference")
    pal = _dec(packed, scale, m, bits=bits, backend="pallas")
    _eq("decode", ref, pal)
    _eq("sender-vs-receiver", m_new, pal)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("stoch", [False, True])
def test_roundtrip_bit_identical(bits, stoch):
    """The DirectQ / backward-gradient wire round trip."""
    x, _ = _data(200, 256, jnp.float32)
    _eq("roundtrip",
        _rt(x, KEY, bits=bits, stoch=stoch, backend="reference"),
        _rt(x, KEY, bits=bits, stoch=stoch, backend="pallas"))


@pytest.mark.parametrize("mode", ["aqsgd", "directq"])
@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("stoch", [False, True])
def test_apply_boundary_forward_and_backward_grads(mode, bits, stoch):
    """The full boundary op, gradients included: the custom_vjp routes
    the backward-gradient quantize/pack through the selected backend and
    both backends must agree bit-for-bit."""
    h = jax.random.normal(jax.random.PRNGKey(4), (4, 7, 256))
    m = 0.1 * jax.random.normal(jax.random.PRNGKey(5), (4, 7, 256))
    seen = jnp.array([True, True, False, True])

    @functools.partial(jax.jit, static_argnames=("backend",))
    def run(h, m, seen, key, *, backend):
        cc = CompressionConfig(mode=mode, fw_bits=bits, bw_bits=bits,
                               stochastic=stoch, backend=backend)

        def loss(h):
            out, m_new = aqsgd.apply_boundary(cc, h, key, m, seen)
            return jnp.sum(out ** 3), m_new

        (l, m_new), g = jax.value_and_grad(loss, has_aux=True)(h)
        return l, m_new, g

    l_r, m_r, g_r = run(h, m, seen, KEY, backend="reference")
    l_p, m_p, g_p = run(h, m, seen, KEY, backend="pallas")
    _eq("loss", l_r, l_p)
    _eq("grad", g_r, g_p)
    if mode == "aqsgd":
        _eq("m_new", m_r, m_p)


@pytest.mark.parametrize("buffer_bits", BITS)
def test_buffer_codec_bit_identical(buffer_bits):
    """z-bit stored messages (§H.5): the fused quantize_pack /
    unpack_dequant kernels must reproduce the reference buffer codec
    exactly through a write→read cycle."""
    ids = jnp.array([3, 7], jnp.int32)
    m = jax.random.normal(KEY, (2, 8, 128))

    @functools.partial(jax.jit, static_argnames=("backend",))
    def cycle(m, *, backend):
        cc = CompressionConfig(mode="aqsgd", buffer_bits=buffer_bits,
                               backend=backend)
        bufs = aqsgd.init_buffers(cc, 2, 10, 8, 128)
        bufs = aqsgd.write_buffer(cc, bufs, 1, ids, m)
        return bufs["codes"], bufs["scale"], \
            aqsgd.read_buffer(cc, bufs, 1, ids, 128)

    c_r, s_r, out_r = cycle(m, backend="reference")
    c_p, s_p, out_p = cycle(m, backend="pallas")
    _eq("codes", c_r, c_p)
    _eq("scale", s_r, s_p)
    _eq("read", out_r, out_p)


def test_pipeline_has_no_unfused_boundary_calls():
    """Every wire-path quantize/pack must route through core.boundary
    — never the unfused Q.quantize→Q.pack_codes chain (that chain
    costs ~6 HBM round-trips per crossing).  The assertion lives in
    the `no-unfused-quantize` lint rule (repro.analysis), which covers
    training/pipeline.py alias-proof; this is its one-line test
    invocation."""
    from repro.analysis import run_rule

    assert run_rule("no-unfused-quantize") == []
