"""The compressed serving plane: sharding rules, quantized KV cache,
delta decode hops, and the continuous batcher.

The serving acceptance gates: stacked param leaves never shard their
layer dim (the old rank heuristic did, for whisper/pixtral-style 2-D
norm stacks); the quantized cache and delta hop go through the SAME
backend-selectable boundary ops as the training wires, so the
reference|pallas bit-parity contract applies; and greedy decode with an
8-bit cache emits the IDENTICAL argmax token stream as the fp32 cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import boundary as B
from repro.launch.mesh import make_debug_mesh
from repro.models import model as Mo
from repro.serving import (ContinuousBatcher, DeltaHopCodec, KVCodec,
                           init_quant_caches, quantize_caches)
from repro.serving import decode as Sv

BITS = [2, 4, 8]


def _params(arch, seed=0):
    cfg = get_config(arch, smoke=True)
    return cfg, Mo.init_params(cfg, jax.random.PRNGKey(seed))


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["whisper-small", "pixtral-12b"])
def test_param_shardings_never_shard_stacked_layer_dim(arch):
    """Regression for the ndim>=3 stacked-leaf heuristic: stackedness
    comes from the tree structure (layers/enc_layers subtree), so a
    stacked 2-D norm leaf (L, d) must keep its LAYER dim unsharded —
    the old rank guess data-sharded dim 0 whenever L divided the data
    axis (always true at L=2, dsize=1|2)."""
    cfg = get_config(arch, smoke=True)
    shapes = jax.eval_shape(lambda k: Mo.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    mesh = make_debug_mesh(1, 1)
    shardings = Sv.param_shardings(cfg, mesh, shapes)
    stacked_2d = 0
    for path, sh in jax.tree_util.tree_leaves_with_path(shardings):
        top = path[0].key
        leaf = shapes
        for p in path:
            leaf = leaf[p.key] if hasattr(p, "key") else leaf[p.idx]
        if top in Sv.STACKED_KEYS:
            assert sh.spec[0] is None if len(sh.spec) else True, \
                (jax.tree_util.keystr(path), leaf.shape, sh.spec)
            if leaf.ndim == 2:
                stacked_2d += 1
    assert stacked_2d > 0      # the arch really has the bug's shape


@pytest.mark.parametrize("arch", ["whisper-small", "pixtral-12b"])
def test_jit_serve_step_lowers_with_fixed_shardings(arch):
    cfg, params = _params(arch)
    caches = Mo.init_caches(cfg, 2, 16, jnp.float32)
    mesh = make_debug_mesh(1, 1)
    step = Sv.jit_serve_step(
        cfg, mesh,
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                     params),
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                     caches),
        jax.ShapeDtypeStruct((2, 1), jnp.int32), donate=False)
    tok = jnp.zeros((2, 1), jnp.int32)
    with mesh:
        logits, _ = step(params, caches, tok)
    assert logits.shape == (2, 1, cfg.vocab_size)


def test_cache_shardings_cover_quantized_and_hop_leaves():
    cfg = get_config("gemma2-9b", smoke=True)
    caches = init_quant_caches(cfg, 2, 16, KVCodec(bits=8),
                               jnp.float32)
    caches["hop_m"] = jnp.zeros((1, 2, 1, cfg.d_model), jnp.float32)
    mesh = make_debug_mesh(1, 1)
    shardings = Sv.cache_shardings(cfg, mesh, caches)
    for name in ("k_codes", "v_codes", "k_scale", "v_scale", "hop_m"):
        assert name in shardings
        assert len(shardings[name].spec) <= caches[name].ndim


# ---------------------------------------------------------------------------
# quantized KV cache
# ---------------------------------------------------------------------------

def test_quantize_caches_layout_and_families():
    cfg = get_config("gemma2-9b", smoke=True)
    codec = KVCodec(bits=4)
    caches = init_quant_caches(cfg, 2, 8, codec, jnp.float32)
    g = codec.group(cfg.head_dim)
    n_scan = cfg.num_layers - cfg.first_dense_layers
    assert caches["k_codes"].shape[:5] == \
        (n_scan, 2, 8, cfg.num_kv_heads, cfg.head_dim // g)
    assert caches["k_codes"].dtype == jnp.uint8
    assert caches["k_scale"].dtype == jnp.float32
    assert "k" not in caches and "v" not in caches
    # ssm has no k/v: passthrough
    scfg = get_config("mamba2-1.3b", smoke=True)
    raw = Mo.init_caches(scfg, 2, 8, jnp.float32)
    assert quantize_caches(scfg, dict(raw), codec).keys() == raw.keys()
    # hybrid's shared block is explicitly unimplemented
    hcfg = get_config("zamba2-2.7b", smoke=True)
    with pytest.raises(NotImplementedError):
        quantize_caches(hcfg, Mo.init_caches(hcfg, 2, 8), codec)


@pytest.mark.parametrize("bits", BITS)
def test_kv_codec_backend_parity(bits):
    """The kv plane inherits the training wires' reference|pallas
    bit-exactness contract: same codes, same scales, same decode."""
    vals = jax.random.normal(jax.random.PRNGKey(3), (2, 4, 2, 64),
                             jnp.float32)

    def enc_dec(backend):
        codec = KVCodec(bits=bits, backend=backend)
        c, s = jax.jit(lambda v: codec.encode(v))(vals)
        out = jax.jit(lambda c, s: codec.decode(c, s, jnp.float32))(c, s)
        return c, s, out

    c_r, s_r, o_r = enc_dec("reference")
    c_p, s_p, o_p = enc_dec("pallas")
    np.testing.assert_array_equal(np.asarray(c_r), np.asarray(c_p))
    np.testing.assert_array_equal(np.asarray(s_r), np.asarray(s_p))
    np.testing.assert_array_equal(np.asarray(o_r), np.asarray(o_p))


def test_kv_zero_store_decodes_to_zeros():
    codec = KVCodec(bits=4)
    store = codec.empty((1, 3, 2, 64))
    out = codec.decode(store["codes"], store["scale"], jnp.float32)
    assert not np.asarray(out).any()


# ---------------------------------------------------------------------------
# delta decode hop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
def test_delta_hop_backend_parity_and_reference_advance(bits):
    """aqsgd hop: the receiver's output IS the sender's new reference
    (Algorithm 2's lockstep), bit-equal across backends."""
    h = jax.random.normal(jax.random.PRNGKey(4), (2, 1, 256))
    m0 = 0.9 * h.astype(jnp.float32)

    def cross(backend):
        codec = DeltaHopCodec(mode="aqsgd", bits=bits, backend=backend)
        state = {"m": m0[None]}
        return jax.jit(lambda s, x: codec.decode_boundary(s, x, 0))(
            state, h)

    (st_r, h_r), (st_p, h_p) = cross("reference"), cross("pallas")
    np.testing.assert_array_equal(np.asarray(h_r), np.asarray(h_p))
    np.testing.assert_array_equal(np.asarray(st_r["m"]),
                                  np.asarray(st_p["m"]))
    # receiver output == advanced reference, and it moved toward h
    np.testing.assert_array_equal(np.asarray(st_r["m"][0]),
                                  np.asarray(h_r, np.float32))
    assert np.abs(h_r - h).max() < np.abs(m0 - h).max() + 1e-6


def test_delta_hop_bytes_below_fp16():
    """The modeled decode-hop payload undercuts even an fp16 hop at
    every codec width — the wire-level acceptance gate (the compiled-
    HLO version lives in test_hlo_cost.py)."""
    b, d = 8, 256
    fp16 = b * d * 2
    for bits in BITS:
        hop = DeltaHopCodec(mode="aqsgd", bits=bits)
        assert hop.hop_bytes(b, d) < fp16, bits
    assert DeltaHopCodec(mode="fp32").hop_bytes(b, d) == b * d * 4


def test_staged_decode_fp32_hop_is_exact():
    """num_stages > 1 with an fp32 (pass-through) hop must be the
    IDENTICAL computation to the single scan — the chunked scan itself
    adds no numerics."""
    cfg, params = _params("gemma2-9b")
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 6), 0,
                              cfg.vocab_size)
    hop = DeltaHopCodec(mode="fp32")

    def run(num_stages, bfn):
        caches = Mo.init_caches(cfg, 2, 8, jnp.float32)
        logits, _ = jax.jit(
            lambda p, c, t: Mo.forward_with_caches(
                p, cfg, t, c, logits_last_only=True,
                num_stages=num_stages, boundary_fn=bfn))(
                    params, caches, toks)
        return np.asarray(logits)

    base = run(1, None)
    staged = run(2, hop.boundary_fn(prefill=False))
    np.testing.assert_array_equal(base, staged)


# ---------------------------------------------------------------------------
# greedy equivalence + batcher
# ---------------------------------------------------------------------------

def _greedy(cfg, params, toks, cache_len, n, kv_codec=None):
    caches = Mo.init_caches(cfg, toks.shape[0], cache_len, jnp.float32)
    if kv_codec is not None:
        caches = quantize_caches(cfg, caches, kv_codec)
    logits, caches = Mo.forward_with_caches(
        params, cfg, toks, caches, logits_last_only=True,
        kv_codec=kv_codec)
    step = jax.jit(lambda p, c, t: Mo.forward_with_caches(
        p, cfg, t, c, logits_last_only=True, kv_codec=kv_codec))
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    out = [np.asarray(tok[:, 0])]
    for _ in range(n - 1):
        logits, caches = step(params, caches, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        out.append(np.asarray(tok[:, 0]))
    return np.stack(out, 1)


def test_greedy_decode_equivalent_fp32_vs_8bit_cache():
    """8-bit quantize-on-append cache emits the IDENTICAL greedy token
    stream as the raw fp32 cache.  Random-init logit margins are thin
    (max-of-V gaussians), so the run is pinned: seed 0's min top-2 gap
    over these 8 steps is ~3x the measured 8-bit logit perturbation
    (group_d=8).  Fresh rows are encoded exactly once — no error
    accumulation — which is what keeps the perturbation flat in t."""
    cfg, params = _params("gemma2-9b", seed=0)
    toks = jax.random.randint(jax.random.PRNGKey(100), (2, 5), 0,
                              cfg.vocab_size)
    base = _greedy(cfg, params, toks, 24, 8)
    q8 = _greedy(cfg, params, toks, 24, 8,
                 KVCodec(bits=8, group_d=8))
    np.testing.assert_array_equal(base, q8)


def test_batcher_mixed_lengths_match_isolated_runs():
    """Slot isolation: mixed-length requests decoded concurrently in a
    2-slot pool (with eviction + re-admission) produce the same tokens
    as each request running ALONE in a 1-slot batcher."""
    cfg, params = _params("gemma2-9b")
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).tolist()
               for n in (3, 6, 4, 6)]

    def serve(num_slots, prompt_list):
        bat = ContinuousBatcher(params, cfg, num_slots=num_slots,
                                cache_len=16)
        for p in prompt_list:
            bat.submit(p, max_new_tokens=4)
        return [r.tokens for r in bat.run()]

    alone = [serve(1, [p])[0] for p in prompts]
    mixed = serve(2, prompts)
    assert mixed == alone
    assert all(len(t) == 4 for t in mixed)


def test_batcher_quantized_and_staged():
    """The pooled decode step composes the kv codec and the delta hop;
    every request still terminates and produces max_new tokens."""
    cfg, params = _params("gemma2-9b")
    bat = ContinuousBatcher(
        params, cfg, num_slots=2, cache_len=16,
        kv_codec=KVCodec(bits=8),
        hop_codec=DeltaHopCodec(mode="aqsgd", bits=8), num_stages=2)
    rng = np.random.default_rng(9)
    for n in (3, 5, 4):
        bat.submit(rng.integers(0, cfg.vocab_size, n).tolist(),
                   max_new_tokens=3)
    reqs = bat.run()
    assert [r.state for r in reqs] == ["DONE"] * 3
    assert all(len(r.tokens) == 3 for r in reqs)


def test_batcher_eos_eviction():
    """EOS frees the slot early: with eos_id covering every token id
    (vocab-wide), each request finishes after ONE token."""
    cfg, params = _params("gemma2-9b")
    bat = ContinuousBatcher(params, cfg, num_slots=1, cache_len=16)
    r1 = bat.submit([1, 2, 3], max_new_tokens=1)
    r2 = bat.submit([4, 5], max_new_tokens=1)
    reqs = bat.run()
    assert reqs == [r1, r2]
    assert r1.state == "DONE" and r2.state == "DONE"
    assert len(r1.tokens) == 1 and len(r2.tokens) == 1
