"""Substrate tests: optimizer (incl. 8-bit moments), schedules,
checkpointing, data pipeline sample identity."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import Dataset, DatasetConfig
from repro.optim import adamw


def _rosenbrock_like(params):
    x, y = params["x"], params["y"]
    return jnp.sum((1 - x) ** 2 + 10.0 * (y - x ** 2) ** 2)


@pytest.mark.parametrize("state_bits", [0, 8])
def test_adamw_optimizes(state_bits):
    cfg = adamw.AdamWConfig(lr=5e-2, warmup_steps=1, total_steps=200,
                            schedule="constant", weight_decay=0.0,
                            state_bits=state_bits)
    params = {"x": jnp.zeros((8,)), "y": jnp.zeros((8,))}
    state = adamw.init_opt_state(params, state_bits=state_bits)
    loss0 = float(_rosenbrock_like(params))

    @jax.jit
    def step(p, s):
        g = jax.grad(_rosenbrock_like)(p)
        return adamw.apply_updates(cfg, p, g, s)

    for _ in range(150):
        params, state = step(params, state)
    assert float(_rosenbrock_like(params)) < 0.05 * loss0


def test_lr_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.lr_at(cfg, s)) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6          # warmup peak
    assert lrs[50] < lrs[10]                   # decaying
    assert lrs[100] == 0.0                     # fully decayed


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "state.npz")
        ckpt.save(path, tree)
        like = jax.tree.map(jnp.zeros_like, tree)
        back = ckpt.restore(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_dataset_sample_identity_across_epochs():
    """AQ-SGD's buffers key on stable sample ids: the same id must map
    to the same tokens in every epoch regardless of shuffling."""
    ds = Dataset(DatasetConfig(num_samples=16, seq_len=8, vocab_size=64,
                               seed=5))
    seen = {}
    for _ in range(3):
        for batch in ds.epoch(4):
            for i, sid in enumerate(batch["sample_ids"]):
                key = int(sid)
                tok = tuple(batch["tokens"][i])
                if key in seen:
                    assert seen[key] == tok, key
                seen[key] = tok
    assert len(seen) == 16


def test_dataset_epoch_shuffles_batches():
    ds = Dataset(DatasetConfig(num_samples=16, seq_len=8, vocab_size=64))
    e1 = [tuple(b["sample_ids"]) for b in ds.epoch(4)]
    e2 = [tuple(b["sample_ids"]) for b in ds.epoch(4)]
    assert e1 != e2                      # shuffled
    assert sorted(sum(map(list, e1), [])) == list(range(16))


def test_textfile_corpus(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_text("hello world, this is a tiny corpus for the tests " * 20)
    ds = Dataset(DatasetConfig(num_samples=8, seq_len=16, vocab_size=256,
                               kind="textfile", path=str(p)))
    b = next(ds.epoch(4))
    assert b["tokens"].shape == (4, 16)
    assert b["tokens"].max() < 256
