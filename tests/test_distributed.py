"""Distributed runtime tests — run in subprocesses because the host
device count must be set before JAX initializes."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow          # multi-process workers, minutes

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_worker(script, arg, timeout=1500):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "workers", script),
         arg],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"\nSTDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.parametrize("check", [
    "fp32_equivalence", "aqsgd_buffers", "zbit_buffers",
    "modes_all_archs", "expert_parallel", "dp_grad_pipeline",
    "dp_wire_parity", "dp_wire_fp16"])
def test_pipeline(check):
    out = run_worker("pipeline_worker.py", check)
    assert f"OK {check}" in out or "OK" in out


def test_launch_train_fp16_wire():
    """The registry-only fp16 DP wire trains end-to-end through the
    real `launch.train` CLI (the acceptance path: a wire that exists
    ONLY as a registry entry reaches the distributed trainer)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--smoke",
         "--distributed", "--data-par", "2", "--stages", "2",
         "--steps", "3", "--batch", "4", "--samples", "8",
         "--seq", "32", "--microbatches", "2",
         "--dp-grad-bits", "4", "--dp-wire", "fp16"],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, f"\nSTDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    assert "final loss" in r.stdout


def test_launch_train_chunked_ring_identical_losses():
    """`--dp-chunks 2` (the double-buffered chunked ring) through the
    real `launch.train` CLI produces the IDENTICAL printed loss stream
    as the monolithic `--dp-chunks 1` run — chunking is scheduling
    only, so with deterministic rounding every step loss matches to
    the printed digit."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    outs = {}
    for chunks in ("1", "2"):
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.train", "--smoke",
             "--distributed", "--data-par", "2", "--stages", "2",
             "--steps", "3", "--batch", "4", "--samples", "8",
             "--seq", "32", "--microbatches", "2", "--no-stochastic",
             "--dp-grad-bits", "4", "--dp-wire", "ring",
             "--dp-chunks", chunks],
            capture_output=True, text=True, timeout=900, env=env)
        assert r.returncode == 0, \
            f"\nSTDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
        outs[chunks] = [ln for ln in r.stdout.splitlines()
                        if "loss" in ln]
    assert outs["1"], outs
    assert outs["1"] == outs["2"], (outs["1"], outs["2"])


def test_checkpoint_state_structs_roundtrip():
    """Every struct `make_state_structs` emits — dense and ZeRO
    segment-sharded opt moments, eval_shape-derived dp_error, raw and
    z-bit buffer dtypes, quantized opt state — survives
    save -> restore bit-identically on a 1-D and a 2x2 mesh, both
    codec backends."""
    out = run_worker("ckpt_worker.py", "run")
    assert "OK ckpt_roundtrip" in out


def test_quantized_psum_mean():
    """b-bit compressed allreduce: replica-consistent and unbiased."""
    out = run_worker("collectives_worker.py", "run")
    assert "OK collectives" in out


def test_dp_grad_wire_matches_simulation():
    """Both error-feedback compressed DP gradient wires — the i32-lane
    code psum and the bandwidth-optimal compressed ring (packed b-bit
    segments on rotation ppermutes + fused local unpack-accumulate) —
    match `grad_compress.compress_allreduce` bit-for-bit, on both
    backends, across ring sizes {2, 3, 5, 8} and compound pod x data
    axes (2x2, 2x3) including non-power-of-two ragged segments."""
    out = run_worker("dp_grad_worker.py", "run")
    assert "OK dp_grad" in out


def test_moe_expert_parallel_numerics():
    """EP dispatch/weight all_to_all == single-device MoE, E<D and E>=D."""
    out = run_worker("moe_ep_worker.py", "run")
    assert "OK moe_ep" in out


def test_dryrun_smoke_mesh():
    """A reduced-config dry-run on a small in-container mesh proves the
    launch path end-to-end (the full 256/512-chip dry-runs are run via
    `python -m repro.launch.dryrun`, recorded in EXPERIMENTS.md)."""
    out = run_worker("dryrun_worker.py", "smoke")
    assert "DRYRUN OK" in out
