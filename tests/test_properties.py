"""Hypothesis property tests for the quantizer, wire packing, and the
fused Pallas boundary kernels.

Collected only when hypothesis is installed (CI installs it via the
`dev` extra); pytest.importorskip keeps collection green without it.
"""
import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import assume, given, settings, strategies as st

from repro.core import boundary as B
from repro.core import collectives as C
from repro.core import quantization as q
from repro.kernels.quant_pack import delta_quantize_pack


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    rows=st.integers(1, 5),
    n=st.integers(1, 130),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_wire_roundtrip_equals_qdq(bits, rows, n, seed):
    """Wire form (quantize→pack→unpack→dequantize) == fake-quant qdq."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (rows, n), dtype=jnp.float32) * 3.0
    codes, scale = q.quantize(x, bits, stochastic=False)
    wire = q.pack_codes(codes, bits)
    xh_wire = q.dequantize(q.unpack_codes(wire, bits, n), scale, bits)
    xh_sim = q.qdq(x, bits, stochastic=False)
    np.testing.assert_allclose(np.asarray(xh_wire), np.asarray(xh_sim),
                               rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
    scale_pow=st.integers(-3, 3),
)
def test_property_quantize_within_grid(bits, seed, scale_pow):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (4, 64)) * (10.0 ** scale_pow)
    codes, _ = q.quantize(x, bits, stochastic=True, key=key)
    assert int(jnp.max(codes)) <= (1 << bits) - 1


@settings(max_examples=100, deadline=None)
@given(rows=st.integers(1, 97), n=st.integers(1, 9),
       chunks=st.integers(1, 12))
def test_property_chunk_geometry_partitions_exactly(rows, n, chunks):
    """`ring_segment_rows` + `ring_chunk_bounds` partition every
    bucket exactly, ragged cases included: the n device segments cover
    [0, rows) disjointly (the last one short when n does not divide
    rows), and the K chunk bounds cover [0, seg) disjointly — sorted,
    adjacent, nonempty, ceil-division-minimal — or raise loudly when
    K exceeds the segment's rows."""
    seg = C.ring_segment_rows(rows, n)
    covered = [i for r in range(n)
               for i in range(r * seg, min((r + 1) * seg, rows))]
    assert covered == list(range(rows))
    if chunks > seg:
        with pytest.raises(ValueError, match="exceeds the segment"):
            C.ring_chunk_bounds(seg, chunks)
        return
    bounds = C.ring_chunk_bounds(seg, chunks)
    assert all(lo < hi for lo, hi in bounds)
    assert bounds[0][0] == 0 and bounds[-1][1] == seg
    assert all(b[0] == a[1] for a, b in zip(bounds, bounds[1:]))
    cw = C.ring_segment_rows(seg, chunks)
    assert all(hi - lo == cw for lo, hi in bounds[:-1])
    # realized chunk count is the ceil-division minimum (may be < K)
    assert len(bounds) == -(-seg // cw) <= chunks


@settings(max_examples=20, deadline=None)
@given(bits=st.sampled_from([2, 4, 8]),
       rows=st.integers(1, 40),
       chunks=st.integers(1, 8),
       seed=st.integers(0, 2 ** 31 - 1))
def test_property_chunked_decode_concat_equals_monolithic(
        bits, rows, chunks, seed):
    """Row-sliced encode/decode under one shared scale concatenates to
    the bit-identical monolithic result: quantization is rowwise, so
    chunk boundaries cannot leak across rows — the invariant that
    makes the chunked ring schedule bit-equal to the monolithic one."""
    assume(chunks <= rows)
    d = 32
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (rows, d), dtype=jnp.float32) * 2.0
    s = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    bounds = C.ring_chunk_bounds(rows, chunks)
    codes_m = B.encode_codes_with_scale(x, s, bits=bits,
                                        stochastic=False,
                                        backend="reference")
    codes_c = jnp.concatenate(
        [B.encode_codes_with_scale(x[lo:hi], s[lo:hi], bits=bits,
                                   stochastic=False,
                                   backend="reference")
         for lo, hi in bounds], axis=0)
    np.testing.assert_array_equal(np.asarray(codes_c),
                                  np.asarray(codes_m))
    dec_m = B.decode_sum_mean(codes_m, s, bits=bits, n=1,
                              backend="reference")
    dec_c = jnp.concatenate(
        [B.decode_sum_mean(codes_m[lo:hi], s[lo:hi], bits=bits, n=1,
                           backend="reference")
         for lo, hi in bounds], axis=0)
    np.testing.assert_array_equal(np.asarray(dec_c), np.asarray(dec_m))


@settings(max_examples=10, deadline=None)
@given(bits=st.sampled_from([2, 4, 8]),
       r=st.sampled_from([4, 32, 128]),
       dscale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 2 ** 31 - 1))
def test_property_roundtrip_error_bounded(bits, r, dscale, seed):
    """|reconstruction - truth| <= one quantization cell, any magnitude."""
    d = 256
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (r, d)) * dscale
    m = jnp.zeros((r, d))
    packed, scale, m_new = delta_quantize_pack(a, m, bits=bits)
    cell = 2.0 * np.asarray(scale) / ((1 << bits) - 1)
    err = np.abs(np.asarray(m_new) - np.asarray(a))
    assert np.all(err <= 0.5 * cell + 1e-6 * dscale)
