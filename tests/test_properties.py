"""Hypothesis property tests for the quantizer, wire packing, and the
fused Pallas boundary kernels.

Collected only when hypothesis is installed (CI installs it via the
`dev` extra); pytest.importorskip keeps collection green without it.
"""
import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import quantization as q
from repro.kernels.quant_pack import delta_quantize_pack


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    rows=st.integers(1, 5),
    n=st.integers(1, 130),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_wire_roundtrip_equals_qdq(bits, rows, n, seed):
    """Wire form (quantize→pack→unpack→dequantize) == fake-quant qdq."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (rows, n), dtype=jnp.float32) * 3.0
    codes, scale = q.quantize(x, bits, stochastic=False)
    wire = q.pack_codes(codes, bits)
    xh_wire = q.dequantize(q.unpack_codes(wire, bits, n), scale, bits)
    xh_sim = q.qdq(x, bits, stochastic=False)
    np.testing.assert_allclose(np.asarray(xh_wire), np.asarray(xh_sim),
                               rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
    scale_pow=st.integers(-3, 3),
)
def test_property_quantize_within_grid(bits, seed, scale_pow):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (4, 64)) * (10.0 ** scale_pow)
    codes, _ = q.quantize(x, bits, stochastic=True, key=key)
    assert int(jnp.max(codes)) <= (1 << bits) - 1


@settings(max_examples=10, deadline=None)
@given(bits=st.sampled_from([2, 4, 8]),
       r=st.sampled_from([4, 32, 128]),
       dscale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 2 ** 31 - 1))
def test_property_roundtrip_error_bounded(bits, r, dscale, seed):
    """|reconstruction - truth| <= one quantization cell, any magnitude."""
    d = 256
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (r, d)) * dscale
    m = jnp.zeros((r, d))
    packed, scale, m_new = delta_quantize_pack(a, m, bits=bits)
    cell = 2.0 * np.asarray(scale) / ((1 << bits) - 1)
    err = np.abs(np.asarray(m_new) - np.asarray(a))
    assert np.all(err <= 0.5 * cell + 1e-6 * dscale)
