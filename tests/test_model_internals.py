"""Correctness tests for the model substrate: SSD vs naive recurrence,
blockwise attention vs dense reference, MoE dispatch vs dense reference,
and prefill/decode cache consistency across families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import model as Mo

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

def naive_ssd(xh, dt, A, Bc, Cc):
    """O(L) recurrence reference: state_{t} = state_{t-1} e^{dt_t A} +
    dt_t x_t B_t ; y_t = C_t . state_t."""
    b, l, h, p = xh.shape
    n = Bc.shape[-1]
    state = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, l, h, p), np.float64)
    xh, dt, A = np.asarray(xh, np.float64), np.asarray(dt, np.float64), \
        np.asarray(A, np.float64)
    Bc, Cc = np.asarray(Bc, np.float64), np.asarray(Cc, np.float64)
    for t in range(l):
        dA = np.exp(dt[:, t] * A)                       # (b,h)
        upd = np.einsum("bh,bhp,bn->bhpn", dt[:, t], xh[:, t], Bc[:, t])
        state = state * dA[..., None, None] + upd
        ys[:, t] = np.einsum("bhpn,bn->bhp", state, Cc[:, t])
    return ys, state


@pytest.mark.parametrize("l,chunk", [(16, 4), (17, 4), (8, 8), (12, 16)])
def test_ssd_chunked_matches_recurrence(l, chunk):
    b, h, p, n = 2, 3, 4, 8
    ks = jax.random.split(KEY, 5)
    xh = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bc = jax.random.normal(ks[3], (b, l, n))
    Cc = jax.random.normal(ks[4], (b, l, n))
    y, fin = S.ssd_chunked(xh, dt, A, Bc, Cc, chunk)
    y_ref, fin_ref = naive_ssd(xh, dt, A, Bc, Cc)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fin), fin_ref, rtol=2e-4,
                               atol=2e-4)


def test_ssd_initial_state_continuation():
    """ssd(x[:l1]) then ssd(x[l1:], init=state) == ssd(x)."""
    b, l, h, p, n, chunk = 1, 24, 2, 4, 8, 4
    ks = jax.random.split(KEY, 5)
    xh = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bc = jax.random.normal(ks[3], (b, l, n))
    Cc = jax.random.normal(ks[4], (b, l, n))
    y_all, fin_all = S.ssd_chunked(xh, dt, A, Bc, Cc, chunk)
    l1 = 12
    y1, s1 = S.ssd_chunked(xh[:, :l1], dt[:, :l1], A, Bc[:, :l1],
                           Cc[:, :l1], chunk)
    y2, s2 = S.ssd_chunked(xh[:, l1:], dt[:, l1:], A, Bc[:, l1:],
                           Cc[:, l1:], chunk, initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(fin_all),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def dense_attention_ref(q, k, v, q_pos, k_pos, window, causal=True,
                        cap=0.0):
    qf = np.asarray(q, np.float64)
    kf = np.asarray(k, np.float64)
    vf = np.asarray(v, np.float64)
    b, sq, h, hd = qf.shape
    s = np.einsum("bqhd,bkhd->bhqk", qf, kf) / np.sqrt(hd)
    if cap > 0:
        s = cap * np.tanh(s / cap)
    qp, kp = np.asarray(q_pos), np.asarray(k_pos)
    vis = np.ones(s.shape, bool)
    if causal:
        vis &= kp[:, None, None, :] <= qp[:, None, :, None]
    vis &= kp[:, None, None, :] > (qp[:, None, :, None] - window)
    s = np.where(vis, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", p, vf)


@pytest.mark.parametrize("window", [10**9, 7])
@pytest.mark.parametrize("block_k", [4, 16, 64])
def test_blockwise_attention_matches_dense(window, block_k):
    b, s, h, hd = 2, 33, 4, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    out = L.blockwise_attention(q, k, v, q_pos=pos, k_pos=pos,
                                window=window, block_k=block_k)
    ref = dense_attention_ref(q, k, v, pos, pos, window)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-5, atol=2e-5)


def test_softcap_attention():
    b, s, h, hd = 1, 16, 2, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd)) * 3
    k = jax.random.normal(ks[1], (b, s, h, hd)) * 3
    v = jax.random.normal(ks[2], (b, s, h, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    out = L.blockwise_attention(q, k, v, q_pos=pos, k_pos=pos,
                                window=10**9, attn_softcap=5.0, block_k=4)
    ref = dense_attention_ref(q, k, v, pos, pos, 10**9, cap=5.0)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_matches_dense_reference_when_no_drop():
    cfg = get_config("mixtral-8x22b", smoke=True)
    p = M.init_moe(KEY, cfg.d_model, cfg.n_experts, cfg.moe_d_ff)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model))
    # capacity = all tokens -> no drops -> must equal dense reference
    out, aux = M.moe_ffn(p, x, top_k=cfg.top_k, capacity_factor=1.0,
                         deterministic_capacity=2 * 16 * cfg.top_k)
    ref = M.moe_dense_reference(p, x, top_k=cfg.top_k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0.0


def test_moe_capacity_drops_bounded():
    """With tight capacity the output degrades gracefully (no NaN) and
    dropped tokens fall back to the shared expert path only."""
    cfg = get_config("deepseek-moe-16b", smoke=True)
    p = M.init_moe(KEY, cfg.d_model, cfg.n_experts, cfg.moe_d_ff,
                   cfg.n_shared_experts)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model))
    out, _ = M.moe_ffn(p, x, top_k=cfg.top_k, capacity_factor=0.5)
    assert np.all(np.isfinite(np.asarray(out)))


# ---------------------------------------------------------------------------
# prefill + decode consistency (the serving path)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", [
    "stablelm-12b", "gemma2-9b", "mixtral-8x22b", "deepseek-moe-16b",
    "mamba2-1.3b", "zamba2-2.7b", "whisper-small", "pixtral-12b",
])
def test_prefill_then_decode_matches_full_forward(arch):
    """logits from [prefill(t0..tn) ; decode(tn+1)] must match the train
    forward on the full sequence at every compared position."""
    cfg = get_config(arch, smoke=True)
    params = Mo.init_params(cfg, KEY)
    b, s_total = 2, 24
    n_pre = 16
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (b, s_total), 0, cfg.vocab_size)
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["patches"] = jax.random.normal(
            key, (b, cfg.num_patches, cfg.d_model)) * 0.02
    if cfg.family == "audio":
        kwargs["frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model)) * 0.02

    # ground truth: the training forward over the full sequence
    batch = {"tokens": tokens, "targets": tokens,
             "mask": jnp.ones((b, s_total), jnp.float32), **kwargs}
    h = Mo.embed_tokens(params, cfg, tokens, kwargs.get("patches"))
    pos = jnp.broadcast_to(jnp.arange(h.shape[1], dtype=jnp.int32),
                           (b, h.shape[1]))
    pr = dict(params)
    if cfg.family == "audio":
        enc = Mo.encode_audio(pr, cfg, kwargs["frames"])
        pr["_enc_out"] = Mo._cross_kv_all(pr, cfg, enc)
    h_full, _, _ = Mo.trunk_forward(pr, cfg, h, pos)
    if cfg.family == "vlm":
        h_full = h_full[:, cfg.num_patches:]
    ref_logits = Mo.lm_logits(params, cfg, h_full)

    # prefill + decode, fp32 caches so comparison is exact-ish
    cache_len = s_total + (cfg.num_patches or 0)
    caches = Mo.init_caches(cfg, b, cache_len, dtype=jnp.float32)
    lp, caches = Mo.forward_with_caches(
        params, cfg, tokens[:, :n_pre], caches, **kwargs)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(
        ref_logits[:, :n_pre]), rtol=5e-3, atol=5e-3)
    for t in range(n_pre, s_total):
        ld, caches = Mo.forward_with_caches(
            params, cfg, tokens[:, t:t + 1], caches)
        np.testing.assert_allclose(
            np.asarray(ld[:, 0]), np.asarray(ref_logits[:, t]),
            rtol=5e-3, atol=5e-3, err_msg=f"{arch} pos {t}")
