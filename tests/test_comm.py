"""`repro.comm` contract tests: CommConfig serialization round-trips,
registry rejection/did-you-mean, registry completeness (every DP wire
carries a byte model the HLO regression exercises), and the removed
legacy kwargs on PipelineConfig / SimTrainConfig (they must raise a
loud migration error, never silently accept or warn)."""
import argparse
import dataclasses
import os

import pytest

from repro.comm import (CommConfig, Codec, PlaneConfig, get_wire,
                        list_wires, wire_names)
from repro.comm import config as comm_cli
from repro.comm import wires as W
from repro.core import collectives as C
from repro.core.aqsgd import CompressionConfig

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _parser():
    ap = argparse.ArgumentParser()
    comm_cli.add_cli_args(ap)
    return ap


SAMPLE_CONFIGS = [
    CommConfig(),
    CommConfig(mode="fp32"),
    CommConfig(dp=PlaneConfig(bits=4)),
    CommConfig(dp=PlaneConfig(bits=4, wire="fp16")),
    CommConfig(mode="directq", fw=PlaneConfig(bits=2),
               bw=PlaneConfig(bits=4), zbuf=PlaneConfig(bits=2),
               dp=PlaneConfig(bits=8, wire="ring-sharded", group_d=256)),
    CommConfig(dp=PlaneConfig(bits=4, chunks=2)),
    CommConfig(dp=PlaneConfig(bits=4, wire="ring-sharded", chunks=4)),
    CommConfig(fw=PlaneConfig(bits=4, stochastic=False),
               bw=PlaneConfig(bits=8, stochastic=False),
               dp=PlaneConfig(bits=4, stochastic=False,
                              error_feedback=False, wire="psum")),
]


# ---------------------------------------------------------------------------
# round trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", SAMPLE_CONFIGS)
def test_json_round_trip(cfg):
    assert CommConfig.from_json(cfg.to_json()) == cfg


@pytest.mark.parametrize("cfg", SAMPLE_CONFIGS)
def test_cli_round_trip(cfg):
    """to_flags -> argparse -> from_args reproduces the config exactly
    (the flat-flag surface and the JSON surface agree)."""
    args = _parser().parse_args(cfg.to_flags())
    assert comm_cli.from_args(args) == cfg


def test_comm_config_file_input(tmp_path):
    """--comm-config accepts a path to a JSON file as well as a
    literal string, and wins over the flat flags."""
    cfg = CommConfig(dp=PlaneConfig(bits=4, wire="fp16"))
    p = tmp_path / "comm.json"
    p.write_text(cfg.to_json())
    args = _parser().parse_args(
        ["--dp-wire", "psum", "--comm-config", str(p)])
    assert comm_cli.from_args(args) == cfg
    args = _parser().parse_args(["--comm-config", cfg.to_json()])
    assert comm_cli.from_args(args) == cfg


def test_to_flags_raises_on_flat_inexpressible():
    """The documented contract: to_flags raises (rather than silently
    dropping) settings the flat surface cannot express."""
    with pytest.raises(ValueError, match="buffer_dtype"):
        CommConfig(buffer_dtype="bfloat16").to_flags()
    with pytest.raises(ValueError, match="group_d"):
        CommConfig(fw=PlaneConfig(bits=4, group_d=64)).to_flags()
    with pytest.raises(ValueError, match="backends differ"):
        CommConfig(fw=PlaneConfig(bits=4,
                                  backend="reference")).to_flags()


def test_fw_bits_zero_requires_fp32():
    """bits=0 means uncompressed; a compressed mode must not silently
    substitute a default width."""
    with pytest.raises(ValueError, match="fw.bits=0"):
        CommConfig(mode="aqsgd", fw=PlaneConfig(bits=0))
    assert CommConfig(mode="fp32", fw=PlaneConfig(bits=0)).fw.bits == 0


def test_json_subset_and_unknown_keys():
    c = CommConfig.from_json('{"dp": {"bits": 4, "wire": "fp16"}}')
    assert c.dp.bits == 4 and c.dp.wire == "fp16"
    assert c.fw.bits == 4 and c.mode == "aqsgd"      # defaults kept
    with pytest.raises(ValueError, match="unknown CommConfig key"):
        CommConfig.from_json('{"pd": {"bits": 4}}')
    with pytest.raises(ValueError, match="unknown dp plane key"):
        CommConfig.from_json('{"dp": {"bitz": 4}}')


# ---------------------------------------------------------------------------
# chunked-schedule knob: validation, registry gating, CLI surface
# ---------------------------------------------------------------------------

def test_chunks_invalid_counts_raise_loudly():
    """chunks must be a positive int: zero, negatives, bools, and
    non-ints all raise with the did-you-mean-style hint, at both the
    config layer and the collective's own geometry check."""
    for bad in (0, -1, True, 1.5, "2"):
        with pytest.raises(ValueError,
                           match="did you mean chunks=1"):
            CommConfig(dp=PlaneConfig(bits=4, chunks=bad))
        with pytest.raises(ValueError,
                           match="did you mean chunks=1"):
            C.ring_chunk_bounds(8, bad)


def test_chunks_exceeding_segment_rows_raise():
    """A chunk ships at least one row per hop: K > seg raises with the
    valid range and the nearest legal count."""
    with pytest.raises(ValueError, match=r"exceeds the segment's 8 "
                                         r"rows.*did you mean "
                                         r"chunks=8"):
        C.ring_chunk_bounds(8, 9)
    # ...and through the byte-model entry point, which validates the
    # same geometry even though chunking never changes its answer
    with pytest.raises(ValueError, match="exceeds the segment"):
        C.ring_wire_bytes((6, 8), 4, n=2, chunks=7)
    assert C.ring_wire_bytes((6, 8), 4, n=2, chunks=3) == \
        C.ring_wire_bytes((6, 8), 4, n=2)


def test_chunks_on_non_chunkable_wires_rejected():
    """dp.chunks != 1 on a wire whose collective has no chunked
    schedule (psum, fp16) must raise loudly, naming the chunkable
    wires — never silently ignore the knob."""
    for wire in ("psum", "fp16"):
        with pytest.raises(ValueError,
                           match=r"not supported by wire.*chunkable "
                                 r"wires: ring, ring-sharded.*did "
                                 r"you mean wire='ring'"):
            CommConfig(dp=PlaneConfig(bits=4, wire=wire, chunks=2))
    # chunkable wires accept it
    assert CommConfig(dp=PlaneConfig(bits=4, chunks=2)).dp.chunks == 2
    assert CommConfig(dp=PlaneConfig(
        bits=4, wire="ring-sharded", chunks=3)).dp.chunks == 3


def test_chunkable_flags_match_registry():
    """`chunkable` is a registry property: exactly the ring-family DP
    wires declare it, and the --dp-chunks help text is generated from
    the registry (naming every chunkable wire)."""
    assert [s.name for s in list_wires("dp-grad") if s.chunkable] == \
        ["ring", "ring-sharded"]
    help_text = _parser().format_help()
    assert "--dp-chunks" in help_text
    assert "ring, ring-sharded" in help_text


def test_dp_chunks_cli_and_json_round_trip():
    """--dp-chunks reaches CommConfig.dp.chunks and survives both the
    flag and JSON surfaces (the parametrized round-trip tests cover
    the full-config equality; this pins the knob's plumbing)."""
    args = _parser().parse_args(["--dp-grad-bits", "4",
                                 "--dp-chunks", "4"])
    cfg = comm_cli.from_args(args)
    assert cfg.dp.chunks == 4
    assert "--dp-chunks" in cfg.to_flags()
    assert CommConfig.from_json(cfg.to_json()) == cfg
    rt = CommConfig.from_json('{"dp": {"bits": 4, "chunks": 2}}')
    assert rt.dp.chunks == 2


# ---------------------------------------------------------------------------
# registry: rejection, did-you-mean, completeness
# ---------------------------------------------------------------------------

def test_unknown_wire_did_you_mean():
    with pytest.raises(ValueError, match="did you mean 'ring-sharded'"):
        CommConfig(dp=PlaneConfig(bits=4, wire="ring-shraded"))
    with pytest.raises(ValueError, match="did you mean 'ring'"):
        get_wire("rng")
    # hopeless names still list the registered set
    with pytest.raises(ValueError, match="registered wires: ring"):
        get_wire("qsgd-topk-v2")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        W.register_wire("ring", summary="dup",
                        wire_bytes=lambda s, b, n: 0)


def test_registry_completeness_dp_byte_models():
    """Every wire registered on the dp-grad plane must carry a
    collective, a simulator, and a positive-int `wire_bytes` model —
    and the HLO worker that pins the models against compiled programs
    (tests/test_hlo_cost.py) must derive its wire list from the
    registry, so a new wire cannot dodge the byte regression."""
    dp = list_wires("dp-grad")
    assert {s.name for s in dp} >= {"ring", "psum", "ring-sharded",
                                    "fp16"}
    for spec in dp:
        assert spec.collective is not None, spec.name
        assert spec.sim_allreduce is not None, spec.name
        for bits in (2, 4, 8):
            b = spec.wire_bytes((128, 256), bits, 4)
            assert isinstance(b, int) and b > 0, (spec.name, bits, b)
    # the measurement worker enrolls wires from the registry itself
    src = open(os.path.join(ROOT, "tests", "workers",
                            "hlo_wire_worker.py")).read()
    assert "wire_names(\"dp-grad\")" in src
    # and the ring/sharded models are the collectives' own
    assert get_wire("ring").wire_bytes((128, 256), 4, 4) == \
        C.ring_wire_bytes((128, 256), 4, n=4)
    assert get_wire("ring-sharded").wire_bytes((128, 256), 4, 4) == \
        C.ring_wire_bytes((128, 256), 4, n=4, sharded=True)


def test_activation_planes_registered():
    """The registry covers all five planes (the unified accounting the
    e2e CSV's plane column and `--list-wires` source)."""
    assert wire_names("fw-activation") == ["ppermute"]
    assert wire_names("bw-gradient") == ["ppermute"]
    assert wire_names("z-buffer") == ["hbm"]
    assert wire_names("kv-cache") == ["paged"]
    assert get_wire("hbm", plane="z-buffer").network is False
    assert get_wire("paged", plane="kv-cache").network is False
    fw = get_wire("ppermute", plane="fw-activation")
    # boundary payload: packed codes + f32 row scales
    assert fw.wire_bytes((8, 64, 512), 4, 1) == \
        8 * 64 * (512 // 2) + 8 * 64 * 4
    kv = get_wire("paged", plane="kv-cache")
    # one grouped append: packed codes + f32 scale per group row;
    # bits=0 falls back to the raw-f32 cache footprint
    assert kv.wire_bytes((8, 1, 4, 64), 8, 1) == 8 * 4 * 64 + 8 * 4 * 4
    assert kv.wire_bytes((8, 1, 4, 64), 0, 1) == 8 * 4 * 64 * 4


# ---------------------------------------------------------------------------
# codec + activation view
# ---------------------------------------------------------------------------

def test_codec_wraps_boundary_ops():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import boundary as B
    codec = Codec(bits=4, stochastic=False, backend="reference")
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64), jnp.float32)
    packed, scale = codec.encode(x)
    pb, sb = B.encode(x, bits=4, stochastic=False, backend="reference")
    np.testing.assert_array_equal(packed, pb)
    np.testing.assert_array_equal(scale, sb)
    np.testing.assert_array_equal(
        codec.decode(packed, scale, d=64),
        B.decode(pb, sb, bits=4, d=64, backend="reference"))
    assert codec.wire_bytes((8, 64)) == 8 * (64 // 2) + 8 * 4
    err = codec.init_state({"w": jnp.zeros((100, 3))}, group_d=32)
    assert err.shape == (-(-300 // 32), 32)


def test_activation_view_matches_legacy_defaults():
    assert CommConfig().activation == CompressionConfig()
    cc = CompressionConfig(mode="directq", fw_bits=2, bw_bits=4,
                           buffer_bits=2, stochastic=False,
                           backend="reference")
    assert CommConfig.from_legacy(cc).activation == cc
    # bw_bits >= 32 (uncompressed backward) round-trips through bits=0
    cc32 = CompressionConfig(bw_bits=32)
    assert CommConfig.from_legacy(cc32).activation == cc32


# ---------------------------------------------------------------------------
# removed legacy kwargs
# ---------------------------------------------------------------------------

def test_pipeline_config_legacy_kwargs_removed():
    """The one-release deprecation shims are gone: passing any
    pre-registry kwarg raises a loud error that names the kwarg and
    points at comm= / from_legacy, and the mirror reader properties no
    longer exist (reads go through comm)."""
    from repro.training import pipeline as PL
    with pytest.raises(TypeError, match=r"dp_wire=.*removed.*"
                                        r"comm=CommConfig"):
        # repro-lint: disable=no-legacy-comm-kwargs (pins the error)
        PL.PipelineConfig(dp_grad_bits=4, dp_wire="ring-sharded",
                          buffer_bits=2)
    with pytest.raises(TypeError, match="compression=.*from_legacy"):
        # repro-lint: disable=no-legacy-comm-kwargs (pins the error)
        PL.PipelineConfig(compression=CompressionConfig(mode="fp32"))
    new = PL.PipelineConfig(comm=CommConfig(
        zbuf=PlaneConfig(bits=2), dp=PlaneConfig(bits=4,
                                                 wire="ring-sharded")))
    assert new.comm.dp.wire == "ring-sharded" and new.comm.zbuf.bits == 2
    # no mirror properties survive — old readers must migrate to comm
    # (the InitVar class attributes remain, but only as inert None
    # defaults for the rejection gate, never comm-derived values)
    for name in ("compression", "buffer_bits", "dp_grad_bits",
                 "dp_grad_group", "dp_wire"):
        assert not isinstance(getattr(type(new), name, None), property)
        assert getattr(new, name, None) is None
    # replace()/with_comm both swap comm cleanly now that the InitVar
    # defaults are all None (nothing re-raises)
    rep = dataclasses.replace(new, warmup=True)
    assert rep.comm == new.comm and rep.warmup
    swapped = new.with_comm(
        CommConfig(dp=PlaneConfig(bits=4, wire="psum")))
    assert swapped.comm.dp.wire == "psum" and swapped.comm.zbuf.bits == 0
    assert dataclasses.replace(
        new, comm=swapped.comm).comm.dp.wire == "psum"
    # the sanctioned migration path reproduces the old kwarg semantics
    via_legacy = PL.PipelineConfig(comm=CommConfig.from_legacy(
        None, dp_grad_bits=4, dp_wire="ring-sharded", buffer_bits=2))
    assert via_legacy.comm == new.comm


def test_sim_config_legacy_kwargs_removed():
    from repro.training import simulated as sim
    with pytest.raises(TypeError, match="dp_sharded=.*removed"):
        # deliberate violation: this test pins the rejection error
        sim.SimTrainConfig(  # repro-lint: disable=no-legacy-comm-kwargs
            compression=CompressionConfig(mode="directq", fw_bits=2,
                                          bw_bits=4),
            dp_grad_bits=4, dp_workers=2, dp_sharded=True)
    new = sim.SimTrainConfig(
        comm=CommConfig(mode="directq", fw=PlaneConfig(bits=2),
                        bw=PlaneConfig(bits=4),
                        dp=PlaneConfig(bits=4, wire="ring-sharded")),
        dp_workers=2)
    assert new.comm.dp_wire_spec.sharded is True
    for name in ("compression", "dp_grad_bits", "dp_grad_group",
                 "dp_sharded"):
        assert not isinstance(getattr(type(new), name, None), property)
        assert getattr(new, name, None) is None
    # from_legacy covers the dp_sharded flag via the wire name
    via_legacy = sim.SimTrainConfig(
        comm=CommConfig.from_legacy(
            CompressionConfig(mode="directq", fw_bits=2, bw_bits=4),
            dp_grad_bits=4, dp_wire="ring-sharded"),
        dp_workers=2)
    assert via_legacy.comm == new.comm
    swapped = new.with_comm(CommConfig(dp=PlaneConfig(bits=4)))
    assert swapped.comm.dp_wire_spec.sharded is False
    assert swapped.comm.dp.bits == 4 and swapped.dp_workers == 2


def test_fp16_wire_sim_trains():
    """The fp16 passthrough trains in the simulated trainer (finite,
    decreasing) — the registry's sim_allreduce hook end-to-end."""
    import jax
    import math
    from repro.configs.base import get_config
    from repro.data.pipeline import Dataset, DatasetConfig
    from repro.optim.adamw import AdamWConfig
    from repro.training import simulated as sim
    cfg = get_config("gpt2-xl-paper", smoke=True).with_(num_layers=2)
    dc = DatasetConfig(num_samples=16, seq_len=16,
                       vocab_size=cfg.vocab_size)
    tcfg = sim.SimTrainConfig(
        num_stages=2,
        comm=CommConfig(dp=PlaneConfig(bits=4, wire="fp16")),
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=6),
        dp_workers=2)
    _, losses = sim.train(cfg, tcfg, Dataset(dc), num_steps=6,
                          batch_size=4, key=jax.random.PRNGKey(0))
    assert all(map(math.isfinite, losses)), losses
    assert losses[-1] < losses[0], losses
