"""Versioned checkpoint subsystem gates (ISSUE 8 tentpole plane 1).

Covers the manifest protocol end-to-end on small synthetic trees plus
the real simulated-trainer state: bit-exact round-trips (incl. bf16 /
bool / uint32 PRNG key data), fail-closed corruption detection (a
single flipped byte in ``arrays.npz`` OR ``manifest.json`` refuses to
load), loud structure/comm-config diffs instead of bare KeyErrors,
keep-last-k rotation, and crash-residue cleanup.  The distributed
`make_state_structs` round-trip (1-D and 2x2 meshes, both codec
backends) lives in tests/workers/ckpt_worker.py (slow tier).
"""
import json
import os
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.comm import CommConfig


def make_tree():
    """A small tree exercising every dtype class the trainer stores:
    bf16 (ml_dtypes, stored as f32), f32, bool, int32, uint32 key."""
    rng = np.random.default_rng(0)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((3, 4)),
                                    jnp.bfloat16),
                   "b": jnp.asarray(rng.standard_normal(4),
                                    jnp.float32)},
        "opt": {"mu": jnp.asarray(rng.standard_normal((3, 4)),
                                  jnp.float32),
                "step": jnp.asarray(7, jnp.int32)},
        "seen": jnp.asarray([True, False, True]),
        "k_run": jnp.asarray([123, 456], jnp.uint32),
    }


def assert_trees_bit_equal(a, b):
    la = jax.tree_util.tree_flatten_with_path(a)[0]
    lb = {ckpt.checkpoint._leaf_key(p): v
          for p, v in jax.tree_util.tree_flatten_with_path(b)[0]}
    assert len(la) == len(lb)
    for p, va in la:
        vb = lb[ckpt.checkpoint._leaf_key(p)]
        assert np.dtype(va.dtype) == np.dtype(vb.dtype), p
        na, nb = np.asarray(va), np.asarray(vb)
        assert na.tobytes() == nb.tobytes(), p


# ---------------------------------------------------------------------------
# legacy single-file API (hardened)
# ---------------------------------------------------------------------------

def test_legacy_roundtrip(tmp_path):
    tree = make_tree()
    path = str(tmp_path / "params.npz")
    ckpt.save(path, tree)
    out = ckpt.restore(path, jax.eval_shape(lambda: tree))
    assert_trees_bit_equal(tree, out)
    assert not [n for n in os.listdir(tmp_path) if ".tmp" in n]


def test_legacy_restore_loud_diff(tmp_path):
    tree = make_tree()
    path = str(tmp_path / "params.npz")
    ckpt.save(path, tree)
    like = jax.eval_shape(lambda: tree)
    del like["opt"]["mu"]                        # -> unexpected
    like["extra"] = jax.ShapeDtypeStruct((2,), jnp.float32)  # missing
    like["params"]["b"] = jax.ShapeDtypeStruct((5,), jnp.float32)
    with pytest.raises(ckpt.CheckpointError) as e:
        ckpt.restore(path, like)
    msg = str(e.value)
    assert "missing from checkpoint: extra" in msg
    assert "unexpected in checkpoint: opt/mu" in msg
    assert "shape mismatch: params/b" in msg


# ---------------------------------------------------------------------------
# manifest protocol
# ---------------------------------------------------------------------------

def test_save_state_roundtrip_bit_exact(tmp_path):
    tree = make_tree()
    comm = CommConfig.from_dict({"mode": "aqsgd", "fw": {"bits": 4},
                                 "dp": {"bits": 4, "wire": "ring"}})
    path = ckpt.save_state(str(tmp_path), tree, step=3, comm=comm,
                           extra={"data_position": 3})
    assert os.path.basename(path) == "step_00000003"
    out, body = ckpt.restore_state(str(tmp_path),
                                   jax.eval_shape(lambda: tree),
                                   comm=comm)
    assert_trees_bit_equal(tree, out)
    assert body["step"] == 3
    assert body["extra"]["data_position"] == 3
    assert body["comm"] == comm.to_dict()
    assert body["fingerprint"] == ckpt.tree_fingerprint(tree)


def test_rotation_and_latest(tmp_path):
    tree = make_tree()
    for s in (2, 4, 6, 8):
        ckpt.save_state(str(tmp_path), tree, step=s, keep=2)
    assert ckpt.checkpoint_steps(str(tmp_path)) == [6, 8]
    assert ckpt.latest_step(str(tmp_path)) == 8
    out, body = ckpt.restore_state(str(tmp_path),
                                   jax.eval_shape(lambda: tree), step=6)
    assert body["step"] == 6
    with pytest.raises(ckpt.CheckpointError, match="available"):
        ckpt.resolve_checkpoint(str(tmp_path), step=2)


def test_recommit_same_step(tmp_path):
    """Replay after recovery re-commits an existing step: the new
    content wins and no tmp residue survives."""
    tree = make_tree()
    ckpt.save_state(str(tmp_path), tree, step=5)
    tree2 = jax.tree_util.tree_map(lambda x: x, tree)
    tree2["opt"]["step"] = jnp.asarray(99, jnp.int32)
    ckpt.save_state(str(tmp_path), tree2, step=5)
    out, _ = ckpt.restore_state(str(tmp_path),
                                jax.eval_shape(lambda: tree))
    assert int(out["opt"]["step"]) == 99
    assert not [n for n in os.listdir(tmp_path) if n.startswith(".tmp")]


def test_orphan_cleanup(tmp_path):
    tree = make_tree()
    ckpt.save_state(str(tmp_path), tree, step=1)
    orphan = tmp_path / ".tmp-999-deadbeef"
    orphan.mkdir()
    (orphan / "arrays.npz").write_bytes(b"partial")
    (tmp_path / "old.tmp123.npz").write_bytes(b"legacy partial")
    removed = ckpt.clean_orphans(str(tmp_path))
    assert sorted(removed) == [".tmp-999-deadbeef", "old.tmp123.npz"]
    assert ckpt.checkpoint_steps(str(tmp_path)) == [1]   # untouched
    assert ckpt.clean_orphans(str(tmp_path)) == []


def test_empty_dir_fails_loudly(tmp_path):
    with pytest.raises(ckpt.CheckpointError, match="no committed"):
        ckpt.resolve_checkpoint(str(tmp_path))


# ---------------------------------------------------------------------------
# fail-closed corruption detection (satellite d)
# ---------------------------------------------------------------------------

def _flip_byte(path, offset=None):
    data = bytearray(open(path, "rb").read())
    offset = len(data) // 2 if offset is None else offset
    data[offset] ^= 0xFF
    open(path, "wb").write(bytes(data))


def test_array_byteflip_fails_closed(tmp_path):
    tree = make_tree()
    path = ckpt.save_state(str(tmp_path), tree, step=1)
    _flip_byte(os.path.join(path, ckpt.ARRAYS_NAME))
    with pytest.raises(ckpt.CheckpointError, match="SHA-256 mismatch"):
        ckpt.restore_state(str(tmp_path), jax.eval_shape(lambda: tree))


def test_array_crc_catches_sha_preserving_swap(tmp_path):
    """Per-array CRCs are verified even when someone rewrites the npz
    (and the manifest's npz_sha256) around a corrupted array."""
    tree = make_tree()
    path = ckpt.save_state(str(tmp_path), tree, step=1)
    npz_path = os.path.join(path, ckpt.ARRAYS_NAME)
    with np.load(npz_path) as data:
        flat = dict(data)
    flat["opt/mu"] = flat["opt/mu"] + 1.0
    with open(npz_path, "wb") as f:
        np.savez(f, **flat)
    mpath = os.path.join(path, ckpt.MANIFEST_NAME)
    manifest = json.load(open(mpath))
    import hashlib
    manifest["body"]["npz_sha256"] = hashlib.sha256(
        open(npz_path, "rb").read()).hexdigest()
    manifest["crc32"] = zlib.crc32(
        ckpt.checkpoint._canonical(manifest["body"]))
    json.dump(manifest, open(mpath, "w"), sort_keys=True,
              separators=(",", ":"))
    with pytest.raises(ckpt.CheckpointError,
                       match="CRC32 mismatch on array 'opt/mu'"):
        ckpt.restore_state(str(tmp_path), jax.eval_shape(lambda: tree))


def test_manifest_byteflip_fails_closed(tmp_path):
    tree = make_tree()
    path = ckpt.save_state(str(tmp_path), tree, step=1)
    mpath = os.path.join(path, ckpt.MANIFEST_NAME)
    # flip inside the fingerprint hex string: still valid JSON, so
    # only the manifest's own CRC can catch it
    raw = open(mpath).read()
    fp = json.loads(raw)["body"]["fingerprint"]
    open(mpath, "w").write(raw.replace(fp, "f" * len(fp), 1))
    with pytest.raises(ckpt.CheckpointError, match="manifest CRC"):
        ckpt.restore_state(str(tmp_path), jax.eval_shape(lambda: tree))
    open(mpath, "w").write(raw[: len(raw) // 2])   # truncated JSON
    with pytest.raises(ckpt.CheckpointError, match="corrupt"):
        ckpt.restore_state(str(tmp_path), jax.eval_shape(lambda: tree))


# ---------------------------------------------------------------------------
# loud mismatch diffs (satellite b)
# ---------------------------------------------------------------------------

def test_structure_mismatch_diff_and_fingerprint(tmp_path):
    tree = make_tree()
    ckpt.save_state(str(tmp_path), tree, step=1)
    like = jax.eval_shape(lambda: tree)
    del like["seen"]
    like["dp_error"] = jax.ShapeDtypeStruct((2, 8), jnp.float32)
    with pytest.raises(ckpt.CheckpointError) as e:
        ckpt.restore_state(str(tmp_path), like)
    msg = str(e.value)
    assert "missing from checkpoint: dp_error" in msg
    assert "unexpected in checkpoint: seen" in msg
    assert "fingerprint" in msg
    assert "different model/comm/optimizer configuration" in msg


def test_comm_mismatch_diff(tmp_path):
    tree = make_tree()
    saved = CommConfig.from_dict({"mode": "aqsgd", "fw": {"bits": 4},
                                  "dp": {"bits": 4, "wire": "ring"}})
    live = CommConfig.from_dict({"mode": "aqsgd", "fw": {"bits": 4},
                                 "dp": {"bits": 8, "wire": "psum"}})
    ckpt.save_state(str(tmp_path), tree, step=1, comm=saved)
    with pytest.raises(ckpt.CheckpointError) as e:
        ckpt.restore_state(str(tmp_path), jax.eval_shape(lambda: tree),
                           comm=live)
    msg = str(e.value)
    assert "dp.bits: checkpoint=4 run=8" in msg
    assert "dp.wire: checkpoint='ring' run='psum'" in msg
    # matching comm loads fine
    out, _ = ckpt.restore_state(str(tmp_path),
                                jax.eval_shape(lambda: tree),
                                comm=saved)
    assert_trees_bit_equal(tree, out)


# ---------------------------------------------------------------------------
# real simulated-trainer state (fast-tier slice of satellite c)
# ---------------------------------------------------------------------------

def test_sim_train_state_roundtrip(tmp_path):
    """The FULL single-host state — params, opt, AQ-SGD message
    buffers (raw + seen), dp_error EF stack — survives bit-exactly."""
    from repro.configs.base import get_config
    from repro.training import simulated as sim
    from repro.optim.adamw import AdamWConfig

    comm = CommConfig.from_dict({"mode": "aqsgd", "fw": {"bits": 4},
                                 "bw": {"bits": 8},
                                 "dp": {"bits": 4, "wire": "ring"}})
    cfg = get_config("gpt2-xl-paper", smoke=True)
    tcfg = sim.SimTrainConfig(num_stages=2, comm=comm,
                              optimizer=AdamWConfig(), dp_workers=2)
    state = sim.init_train_state(cfg, tcfg, 16, 32, jax.random.PRNGKey(3))
    ckpt.save_state(str(tmp_path), state, step=11, comm=comm)
    out, body = ckpt.restore_state(
        str(tmp_path), jax.eval_shape(lambda: state), comm=comm)
    assert body["step"] == 11
    assert_trees_bit_equal(state, out)
