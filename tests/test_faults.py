"""Fault injection + guarded recovery gates (ISSUE 8 planes 2 and 3).

Fast tier: the `FaultPlan` grammar, the internal fault-wrapper wires
(registered but HIDDEN from enumeration), corruption semantics, the
in-graph `guard_dp_pair` bit-exactness contract, host-side
`check_train_state` attribution on synthetic states, and the serving
batcher's slot-level isolation (poisoned request evicted to
DONE(error), surviving slots' token streams bit-identical).

Slow tier: the headline ISSUE-8 gates end-to-end through
`launch.runner` — kill-and-resume bit-parity for every compressed DP
wire {psum, ring, ring-sharded} with EF + activation compression on,
fault -> detect (named plane/wire/step) -> recover-from-checkpoint
bit-parity per plane, and the real CLI `--kill-at` (exit 17) /
`--resume` path in subprocesses.
"""
import os
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig, faults as F, wires as W
from repro.data.pipeline import Dataset, DatasetConfig

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# FaultPlan grammar
# ---------------------------------------------------------------------------

def test_plan_parse_roundtrip():
    plan = F.FaultPlan.parse("3:dp:nan-scale, 5:fw:drop-hop")
    assert plan.text() == "3:dp:nan-scale,5:fw:drop-hop"
    assert bool(plan)
    assert [s.kind for s in plan.at(3)] == ["nan-scale"]
    assert plan.at(3, "fw") == []
    assert plan.at(4) == []
    assert not F.FaultPlan.parse("")
    assert F.FaultPlan.parse("") == F.FaultPlan()


def test_plan_parse_errors():
    with pytest.raises(ValueError, match="step:plane:kind"):
        F.FaultPlan.parse("3:dp")
    with pytest.raises(ValueError, match="unknown fault plane"):
        F.FaultPlan.parse("3:qq:nan-scale")
    with pytest.raises(ValueError, match="unknown fault kind"):
        F.FaultPlan.parse("3:dp:meteor")
    # all-zero payloads are legitimate on bw/kv: drop-hop rejected
    with pytest.raises(ValueError, match="not injectable"):
        F.FaultPlan.parse("3:kv:drop-hop")
    with pytest.raises(ValueError, match="< 0"):
        F.FaultPlan.parse("-1:dp:nan-scale")


# ---------------------------------------------------------------------------
# internal fault-wrapper wires (registry pattern, hidden from enumeration)
# ---------------------------------------------------------------------------

def test_fault_wire_registered_but_hidden():
    name = F.fault_wire("ring", "nan-scale")
    assert name == "ring+fault-nan-scale"
    assert name == F.fault_wire("ring", "nan-scale")   # idempotent
    spec = W.get_wire(name)                            # resolvable
    assert spec.internal and spec.plane == "dp-grad"
    assert spec.chunkable == W.get_wire("ring").chunkable
    # enumeration (CLI choices, --list-wires, registry-completeness
    # gates in test_comm/test_hlo_cost) never sees internal wires
    assert name not in W.wire_names("dp-grad")
    assert name in W.wire_names("dp-grad", include_internal=True)
    assert all(not s.internal for s in W.list_wires())


def test_faulted_comm_swaps_wire():
    comm = CommConfig.from_dict({"dp": {"bits": 4, "wire": "ring"}})
    spec = F.FaultSpec(3, "dp", "corrupt-codes")
    fc = F.faulted_comm(comm, spec)
    assert fc.dp.wire == "ring+fault-corrupt-codes"
    assert comm.dp.wire == "ring"
    with pytest.raises(ValueError, match="dp.bits"):
        F.faulted_comm(CommConfig.from_dict({}), spec)


# ---------------------------------------------------------------------------
# corruption semantics + in-graph guard
# ---------------------------------------------------------------------------

def test_corrupt_array_kinds():
    x = jnp.ones((2, 3), jnp.float32)
    cc = np.asarray(F.corrupt_array(x, "corrupt-codes"))
    assert np.abs(cc).max() > F.GUARD_MAX and np.isfinite(cc).all()
    assert np.isnan(np.asarray(F.corrupt_array(x, "nan-scale"))).all()
    assert not np.asarray(F.corrupt_array(x, "drop-hop")).any()
    # bf16 (ml_dtypes, numpy kind 'V') is corrupted too
    b = F.corrupt_array(jnp.ones((4,), jnp.bfloat16), "nan-scale")
    assert np.isnan(np.asarray(b).astype(np.float32)).all()
    # ints/bools pass through unchanged (codes corruption is modeled
    # post-decode on the float payload)
    i = jnp.arange(4)
    assert F.corrupt_array(i, "nan-scale") is i


def test_guard_dp_pair_clean_passthrough_bit_exact():
    g = {"a": jnp.asarray([1.5, -2.25]), "b": jnp.asarray([[3e20]])}
    e = jnp.asarray([0.125, 7.0])
    og, oe = jax.jit(F.guard_dp_pair)(g, e)
    for a, b in zip(jax.tree_util.tree_leaves((g, e)),
                    jax.tree_util.tree_leaves((og, oe))):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


@pytest.mark.parametrize("bad", [
    jnp.asarray([1.0, np.nan]),            # non-finite
    jnp.asarray([1.0, 5e31]),              # above GUARD_MAX
    jnp.asarray([0.0, 0.0]),               # all-zero (dropped hop)
])
def test_guard_dp_pair_poisons_mean_and_carry(bad):
    e = jnp.asarray([0.5, 0.5])
    og, oe = F.guard_dp_pair({"g": bad}, {"g": e})
    assert np.isnan(np.asarray(og["g"])).all()
    assert np.isnan(np.asarray(oe["g"])).all()


def test_guard_dp_pair_expect_nonzero_off():
    """ZeRO per-device segments can be legitimately all-zero (padding
    rows): expect_nonzero=False must pass zeros through untouched."""
    z = {"g": jnp.zeros((3,))}
    og, oe = F.guard_dp_pair(z, z, expect_nonzero=False)
    assert not np.asarray(og["g"]).any()
    assert not np.isnan(np.asarray(oe["g"])).any()


# ---------------------------------------------------------------------------
# host-side attribution on synthetic states
# ---------------------------------------------------------------------------

COMM_FULL = CommConfig.from_dict({
    "mode": "aqsgd", "fw": {"bits": 4}, "bw": {"bits": 8},
    "dp": {"bits": 4, "wire": "ring"}})


def _clean_state():
    return {
        "params": {"w": jnp.ones((2, 2))},
        "opt": {"mu": {"w": jnp.zeros((2, 2))}},
        "dp_error": jnp.zeros((2, 8)),
        "buffers": {"seen": [jnp.asarray([True, False])],
                    "m": [jnp.ones((2, 4, 8), jnp.bfloat16)]},
    }


def _raises_plane(state, loss=None):
    with pytest.raises(F.WireFaultError) as e:
        F.check_train_state(state, comm=COMM_FULL, step=4, loss=loss)
    return e.value


def test_check_train_state_clean():
    assert F.check_train_state(_clean_state(), comm=COMM_FULL, step=1,
                               loss=2.5) is None


def test_attribution_dp_error():
    s = _clean_state()
    s["dp_error"] = s["dp_error"].at[0, 0].set(np.nan)
    err = _raises_plane(s)
    assert (err.plane, err.wire, err.step) == ("dp", "ring", 4)
    assert "dp_error" in err.detail
    assert "plane=dp wire='ring' step=4" in str(err)


def test_attribution_buffers_beat_dp_error():
    """Buffers are written from the forward pass — a later DP decode
    cannot contaminate them, so bad buffers attribute to fw even when
    the NaN also reached dp_error."""
    s = _clean_state()
    s["buffers"]["m"][0] = F.corrupt_array(s["buffers"]["m"][0],
                                           "nan-scale")
    s["dp_error"] = s["dp_error"].at[0, 0].set(np.nan)
    assert _raises_plane(s).plane == "fw"


def test_attribution_buffer_drop_hop_sentinel():
    s = _clean_state()
    s["buffers"]["m"][0] = jnp.zeros_like(s["buffers"]["m"][0])
    err = _raises_plane(s)
    assert err.plane == "fw"
    assert "all-zero stored message" in err.detail


def test_attribution_params_to_bw():
    s = _clean_state()
    s["params"]["w"] = F.corrupt_array(s["params"]["w"],
                                       "corrupt-codes")
    assert _raises_plane(s).plane == "bw"


def test_attribution_loss():
    err = _raises_plane(_clean_state(), loss=float("nan"))
    assert err.plane == "bw" and "loss" in err.detail


# ---------------------------------------------------------------------------
# serving batcher: slot-level isolation (kv plane)
# ---------------------------------------------------------------------------

def _serve_cfg():
    from repro.configs.base import get_config
    from repro.models import model as Mo
    cfg = get_config("gemma2-9b", smoke=True)
    return cfg, Mo.init_params(cfg, jax.random.PRNGKey(0))


def test_slot_flags():
    pool = {"pos": jnp.zeros((3,), jnp.int32),
            "k": jnp.zeros((2, 3, 4, 8), jnp.bfloat16),
            "codes": jnp.zeros((2, 3, 4), jnp.uint8)}
    assert not F.slot_flags(pool).any()
    pool["k"] = pool["k"].at[1, 2, 0, 0].set(np.nan)
    assert list(F.slot_flags(pool)) == [False, False, True]


def test_batcher_evicts_poisoned_slot_survivors_bit_identical():
    from repro.serving import ContinuousBatcher
    cfg, params = _serve_cfg()
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).tolist()
               for n in (3, 5, 4)]

    def serve(plan):
        bat = ContinuousBatcher(params, cfg, num_slots=2, cache_len=16,
                                fault_plan=plan)
        for p in prompts:
            bat.submit(p, max_new_tokens=6)
        return bat.run()

    base = serve(None)
    assert all(r.state == "DONE" and not r.error for r in base)
    hit = serve(F.FaultPlan.parse("2:kv:nan-scale"))
    victim, survivors = hit[0], hit[1:]
    assert victim.state == "DONE"
    assert "plane=kv" in victim.error and "tick=2" in victim.error
    assert len(victim.tokens) < 6         # cut short, not completed
    # vmapped row independence + full-row rewrite on re-admission:
    # every other request's stream is bit-identical to the clean run
    for b, h in zip(base[1:], survivors):
        assert not h.error
        assert h.tokens == b.tokens


def test_batcher_admission_guard_rejects_poisoned_prefill():
    from repro.serving import ContinuousBatcher
    cfg, params = _serve_cfg()
    params = jax.tree_util.tree_map(
        lambda l: F.corrupt_array(l, "nan-scale"), params)
    bat = ContinuousBatcher(params, cfg, num_slots=1, cache_len=16,
                            guard=True)
    req = bat.submit([1, 2, 3], max_new_tokens=4)
    bat.run(max_ticks=4)
    assert req.state == "DONE"
    assert "corrupt prefill payload" in req.error
    assert bat._slots == [None]           # never occupied a slot


# ---------------------------------------------------------------------------
# end-to-end through launch.runner (slow tier)
# ---------------------------------------------------------------------------

def _mk(comm_dict):
    from repro.configs.base import get_config
    from repro.optim.adamw import AdamWConfig
    from repro.training import simulated as sim
    cfg = get_config("gpt2-xl-paper", smoke=True)
    comm = CommConfig.from_dict(comm_dict)
    tcfg = sim.SimTrainConfig(num_stages=2, comm=comm,
                              optimizer=AdamWConfig(lr=1e-3,
                                                    warmup_steps=1,
                                                    total_steps=8),
                              dp_workers=2)
    return cfg, tcfg


def _run(cfg, tcfg, num_steps, *, ckpt_dir="", save_every=0,
         resume=False, fault=""):
    from repro.launch import runner
    ds = Dataset(DatasetConfig(num_samples=32, seq_len=32,
                               vocab_size=cfg.vocab_size))
    out = []
    state, losses = runner.run_sim_training(
        cfg, tcfg, ds, num_steps=num_steps, batch_size=4, log_every=1,
        ckpt_dir=ckpt_dir, save_every=save_every, resume=resume,
        fault_plan=F.FaultPlan.parse(fault),
        print_fn=lambda s: out.append(s))
    return losses, out


@pytest.mark.slow
def test_runner_matches_sim_train_bit_for_bit():
    """Checkpointing off + no faults: the runner IS `sim.train` — the
    same key discipline, the same jitted step, the same loss bits."""
    from repro.training import simulated as sim
    cfg, tcfg = _mk({"mode": "aqsgd", "fw": {"bits": 4},
                     "bw": {"bits": 8},
                     "dp": {"bits": 4, "wire": "ring"}})
    losses, _ = _run(cfg, tcfg, 6)
    ds = Dataset(DatasetConfig(num_samples=32, seq_len=32,
                               vocab_size=cfg.vocab_size))
    _, ref = sim.train(cfg, tcfg, ds, num_steps=6, batch_size=4)
    assert losses == ref


@pytest.mark.slow
@pytest.mark.parametrize("wire", ["psum", "ring", "ring-sharded"])
def test_kill_and_resume_bit_parity(wire, tmp_path):
    """The headline gate: train to step k with periodic checkpoints,
    'die', resume in a fresh call — the concatenated loss stream is
    bit-identical to the uninterrupted run.  EF + activation
    compression on, for every compressed DP wire."""
    cfg, tcfg = _mk({"mode": "aqsgd", "fw": {"bits": 4},
                     "bw": {"bits": 8},
                     "dp": {"bits": 4, "wire": wire}})
    base, _ = _run(cfg, tcfg, 8)
    d = str(tmp_path / wire)
    first, _ = _run(cfg, tcfg, 5, ckpt_dir=d, save_every=2)
    resumed, out = _run(cfg, tcfg, 8, ckpt_dir=d, resume=True)
    # the interrupted run commits a final step-5 checkpoint on exit;
    # mid-interval resume (replay overlap) is exercised by the fault
    # and CLI --kill-at gates below
    assert any(o.startswith("resumed from step 5") for o in out)
    assert first == base[:5]
    assert resumed == base[5:]


@pytest.mark.slow
@pytest.mark.parametrize("fault", [
    "4:dp:corrupt-codes", "4:dp:drop-hop", "4:fw:nan-scale",
    "4:bw:corrupt-codes", "4:zbuf:drop-hop"])
def test_fault_detect_attribute_recover_bit_parity(fault, tmp_path):
    """Inject on every plane: the guard names the injected plane/wire/
    step, recovery replays from the last good checkpoint, and the
    final loss stream is bit-identical to the clean run."""
    plane = fault.split(":")[1]
    comm_dict = {"mode": "aqsgd", "fw": {"bits": 4}, "bw": {"bits": 8},
                 "dp": {"bits": 4, "wire": "ring"}}
    if plane == "zbuf":
        comm_dict["zbuf"] = {"bits": 4}
    cfg, tcfg = _mk(comm_dict)
    base, _ = _run(cfg, tcfg, 8)
    d = str(tmp_path / "ck")
    losses, out = _run(cfg, tcfg, 8, ckpt_dir=d, save_every=2,
                       fault=fault)
    tripped = [o for o in out if o.startswith("guard tripped")]
    assert tripped, out
    assert f"plane={plane}" in tripped[0]
    assert "step=4" in tripped[0]
    assert any(o.startswith("recovered from checkpoint") for o in out)
    assert losses == base


@pytest.mark.slow
def test_fault_without_checkpoint_reraises():
    cfg, tcfg = _mk({"mode": "aqsgd", "fw": {"bits": 4},
                     "bw": {"bits": 8},
                     "dp": {"bits": 4, "wire": "ring"}})
    with pytest.raises(ValueError, match="--fault/--resume need"):
        _run(cfg, tcfg, 6, fault="3:dp:nan-scale")


# ---------------------------------------------------------------------------
# the real CLI: --kill-at (exit 17) then --resume (slow tier)
# ---------------------------------------------------------------------------

def _cli(extra, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--smoke",
         "--stages", "2", "--steps", "12", "--batch", "4",
         "--samples", "16", "--seq", "32", "--mode", "aqsgd",
         "--fw-bits", "4", "--bw-bits", "8", "--dp-grad-bits", "4",
         "--dp-wire", "ring"] + extra,
        capture_output=True, text=True, timeout=timeout, env=env)


def _loss_lines(stdout):
    return [ln for ln in stdout.splitlines()
            if re.match(r"(step\s+\d+ loss|final loss)", ln)]


@pytest.mark.slow
def test_cli_kill_resume_bit_parity(tmp_path):
    base = _cli([])
    assert base.returncode == 0, base.stderr[-3000:]
    d = str(tmp_path / "ck")
    killed = _cli(["--ckpt-dir", d, "--save-every", "3",
                   "--kill-at", "7"])
    from repro.launch.runner import KILL_EXIT_CODE
    assert killed.returncode == KILL_EXIT_CODE, \
        (killed.returncode, killed.stdout, killed.stderr[-2000:])
    assert "killing at step 7" in killed.stdout
    resumed = _cli(["--ckpt-dir", d, "--save-every", "3", "--resume"])
    assert resumed.returncode == 0, resumed.stderr[-3000:]
    assert "resumed from step 6" in resumed.stdout
    # step-10 line carries the loss bits (float.hex); final loss is
    # the mean of the last 5 — both must match the uninterrupted run
    base_lines = _loss_lines(base.stdout)
    res_lines = _loss_lines(resumed.stdout)
    assert res_lines == [ln for ln in base_lines
                         if not ln.startswith("step     0 ")]


@pytest.mark.slow
def test_cli_fault_recovers(tmp_path):
    d = str(tmp_path / "ck")
    base = _cli([])
    hit = _cli(["--ckpt-dir", d, "--save-every", "3",
                "--fault", "5:dp:nan-scale"])
    assert hit.returncode == 0, hit.stderr[-3000:]
    assert "guard tripped" in hit.stdout
    assert "plane=dp" in hit.stdout and "step=5" in hit.stdout
    assert "recovered from checkpoint step 3" in hit.stdout
    assert _loss_lines(hit.stdout) == _loss_lines(base.stdout)
