"""Subprocess worker: quantized psum-mean over 4 host devices.

The b-bit compressed allreduce must be (a) exact in expectation
(stochastic rounding + shared scale is unbiased) and (b) within one
quantization cell of the true mean deterministically.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.collectives import quantized_psum_mean
from repro.launch.mesh import make_mesh_auto, shard_map


def main():
    mesh = make_mesh_auto((4,), ("d",))
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 256))
    true_mean = jnp.mean(x, axis=0)

    for bits in (4, 8):
        def f(xs, key):
            return quantized_psum_mean(xs[0], "d", bits, key[0],
                                       stochastic=True)[None]

        fn = jax.jit(shard_map(f, mesh, (P("d"), P("d")), P("d")))
        keys = jax.random.split(jax.random.PRNGKey(1), 4)
        # each device returns the same mean; average over repeats to test
        # unbiasedness
        reps = []
        for r in range(64):
            ks = jax.random.split(jax.random.PRNGKey(100 + r), 4)
            out = fn(x, ks)
            np.testing.assert_allclose(np.asarray(out[0]),
                                       np.asarray(out[3]), atol=0,
                                       err_msg="replicas differ")
            reps.append(np.asarray(out[0]))
        est = np.mean(reps, axis=0)
        cell = 2.0 * float(jnp.max(jnp.abs(x))) / ((1 << bits) - 1)
        err = np.max(np.abs(est - np.asarray(true_mean)))
        print(f"bits={bits}: |E[q-mean] - mean| = {err:.4f} "
              f"(cell {cell:.4f})")
        assert err < 0.25 * cell + 5e-3, (bits, err, cell)
    print("OK collectives")


if __name__ == "__main__":
    main()
