"""Subprocess worker: expert-parallel MoE numerics on 4 host devices.

The EP path (dispatch all_to_all + weight all_to_all + per-device expert
compute) must match the single-device reference bit-for-bit in both
regimes (E < D and E >= D).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh_auto, shard_map
from repro.models import moe as M


def main():
    mesh = make_mesh_auto((4,), ("data",))
    for E, topk in [(2, 1), (4, 2), (8, 2), (16, 4)]:
        d, ff, B, S = 32, 64, 4, 16
        p = M.init_moe(jax.random.PRNGKey(E), d, E, ff)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))

        def ep(xb):
            out, _ = M.moe_ffn(p, xb, top_k=topk, capacity_factor=8.0,
                               ep_axis="data", ep_size=4)
            return out

        ep_sharded = jax.jit(shard_map(ep, mesh, P("data"), P("data")))
        o_ref = jax.vmap(lambda xb: M.moe_ffn(
            p, xb[None], top_k=topk, capacity_factor=8.0)[0][0])(x)
        o_ep = ep_sharded(x)
        err = float(jnp.max(jnp.abs(o_ref - o_ep)))
        print(f"E={E} top{topk}: max err {err:.2e}")
        assert err < 1e-5, (E, err)
    print("OK moe_ep")


if __name__ == "__main__":
    main()
