"""Subprocess worker: distributed pipeline correctness on 4 host devices.

Run as: python tests/workers/pipeline_worker.py <check>
Checks:
  fp32_equivalence — pipeline fp32 loss == monolithic loss_fn loss
  aqsgd_buffers    — warmup step fills buffers with boundary activations;
                     compressed steps then train with finite losses and a
                     shrinking delta magnitude
  modes_all_archs  — one pipeline step for dense/moe/ssm/hybrid/audio/vlm
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.config import CommConfig
from repro.configs.base import get_config
from repro.core.aqsgd import CompressionConfig
from repro.launch.mesh import make_debug_mesh
from repro.models import model as Mo
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.training import pipeline as PL


def build(arch, mode, *, num_layers=None, warmup=False, M=2, Bg=4, S=32,
          lr=0.0, buffer_bits=0, dp_grad_bits=0, dp_wire="ring",
          dp_chunks=1):
    cfg = get_config(arch, smoke=True)
    if num_layers:
        cfg = cfg.with_(num_layers=num_layers)
    mesh = make_debug_mesh(2, 2)
    comm = CommConfig.from_legacy(
        CompressionConfig(mode=mode, fw_bits=4, bw_bits=8),
        buffer_bits=buffer_bits, dp_grad_bits=dp_grad_bits,
        dp_wire=dp_wire)
    if dp_chunks != 1:
        comm = comm.with_(dp=comm.dp.with_(chunks=dp_chunks))
    pcfg = PL.PipelineConfig(
        microbatches=M, warmup=warmup, remat=True, comm=comm)
    step, meta = PL.make_train_step(
        cfg, pcfg, mesh, AdamWConfig(lr=lr, warmup_steps=1,
                                     schedule="constant"),
        global_batch=Bg, seq_len=S, buffer_samples=Bg // 2)
    params = PL.to_pipeline_params(
        cfg, Mo.init_params(cfg, jax.random.PRNGKey(0)), 2)
    if dp_grad_bits and dp_wire == "ring-sharded":
        opt_state = PL.init_sharded_opt(pcfg, params, 2)
    else:
        opt_state = adamw.init_opt_state(params)
    state = {"params": params, "opt": opt_state}
    if dp_grad_bits:
        state["dp_error"] = PL.init_dp_error(pcfg, params, 2)
    if mode == "aqsgd":
        trunk_seq = meta["trunk_seq"]
        if buffer_bits:
            structs = PL.buffer_structs(pcfg, 2, Bg, trunk_seq,
                                        cfg.d_model)
            state["m_out"] = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), structs)
            state["m_in"] = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), structs)
        else:
            state["m_out"] = jnp.zeros((2, Bg, trunk_seq, cfg.d_model),
                                       jnp.bfloat16)
            state["m_in"] = jnp.zeros_like(state["m_out"])
    n_text = S - (cfg.num_patches or 0)
    bmb = Bg // M
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1),
                                     (M, bmb, n_text), 0, cfg.vocab_size),
        "targets": jax.random.randint(jax.random.PRNGKey(2),
                                      (M, bmb, n_text), 0, cfg.vocab_size),
        "mask": jnp.ones((M, bmb, n_text), jnp.float32),
        "sample_ids": (jnp.arange(Bg, dtype=jnp.int32)
                       % (Bg // 2)).reshape(M, bmb),
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(4), (M, bmb, cfg.num_patches, cfg.d_model),
            jnp.float32) * 0.02
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(5), (M, bmb, cfg.encoder_seq, cfg.d_model),
            jnp.float32) * 0.02
    return cfg, step, state, batch


def check_fp32_equivalence():
    arch = "gpt2-xl-paper"
    cfg, step, state, batch = build(arch, "fp32", num_layers=4)
    _, metrics = step(state, batch, jax.random.PRNGKey(3))
    pipe_loss = float(metrics["loss"])
    params = Mo.init_params(cfg.with_(num_layers=4), jax.random.PRNGKey(0))
    flat = {k: v.reshape(-1, *v.shape[2:]) for k, v in batch.items()}
    ref_loss, _ = Mo.loss_fn(params, cfg.with_(num_layers=4), flat)
    print("pipe", pipe_loss, "ref", float(ref_loss))
    np.testing.assert_allclose(pipe_loss, float(ref_loss), rtol=2e-4)
    print("OK fp32_equivalence")


def check_aqsgd_buffers():
    cfg, step, state, batch = build("gpt2-xl-paper", "aqsgd", num_layers=4,
                                    warmup=True, lr=1e-3)
    key = jax.random.PRNGKey(3)
    state1, m1 = step(state, batch, key)
    assert float(jnp.sum(jnp.abs(state1["m_out"].astype(jnp.float32)))) > 0
    # m_in of stage k must equal m_out of stage k-1 (bit-identical copies)
    mo = np.asarray(state1["m_out"].astype(jnp.float32))
    mi = np.asarray(state1["m_in"].astype(jnp.float32))
    np.testing.assert_allclose(mi[1], mo[0], atol=0)
    # compressed steps after warmup
    cfg2, step2, _, _ = build("gpt2-xl-paper", "aqsgd", num_layers=4,
                              warmup=False, lr=1e-3)
    losses = []
    st = state1
    for i in range(4):
        st, met = step2(st, batch, jax.random.fold_in(key, i))
        losses.append(float(met["loss"]))
        np.testing.assert_allclose(
            np.asarray(st["m_in"].astype(jnp.float32))[1],
            np.asarray(st["m_out"].astype(jnp.float32))[0], atol=0)
    assert np.all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses
    print("OK aqsgd_buffers", losses)


def check_zbit_buffers():
    """§H.5 z-bit stored messages through the real pipeline: the fused
    buffer codec keeps both replicas' codes bit-identical and training
    stays finite."""
    cfg, step, state, batch = build("gpt2-xl-paper", "aqsgd", num_layers=4,
                                    warmup=True, lr=1e-3, buffer_bits=4)
    key = jax.random.PRNGKey(3)
    st, _ = step(state, batch, key)
    assert int(jnp.sum(st["m_out"]["codes"])) > 0
    np.testing.assert_array_equal(np.asarray(st["m_in"]["codes"])[1],
                                  np.asarray(st["m_out"]["codes"])[0])
    _, step2, _, _ = build("gpt2-xl-paper", "aqsgd", num_layers=4,
                           warmup=False, lr=1e-3, buffer_bits=4)
    losses = []
    for i in range(3):
        st, met = step2(st, batch, jax.random.fold_in(key, i))
        losses.append(float(met["loss"]))
        np.testing.assert_array_equal(
            np.asarray(st["m_in"]["codes"])[1],
            np.asarray(st["m_out"]["codes"])[0])
        np.testing.assert_array_equal(
            np.asarray(st["m_in"]["scale"])[1],
            np.asarray(st["m_out"]["scale"])[0])
    assert np.all(np.isfinite(losses)), losses
    print("OK zbit_buffers", losses)


def check_modes_all_archs():
    for arch in ["gemma2-9b", "deepseek-moe-16b", "mamba2-1.3b",
                 "zamba2-2.7b", "whisper-small", "pixtral-12b"]:
        cfg, step, state, batch = build(arch, "aqsgd", lr=1e-3)
        _, metrics = step(state, batch, jax.random.PRNGKey(3))
        l = float(metrics["loss"])
        assert np.isfinite(l), (arch, l)
        print("OK", arch, l)
    print("OK modes_all_archs")





def check_dp_grad_pipeline():
    """Fig. 5 end-to-end mode through the real shard_map pipeline: the
    compressed DP gradient wire (bucketed codec + int32 code psum +
    per-rank error feedback) trains with finite decreasing losses, and
    the carried error state becomes active after the first step."""
    cfg, step, state, batch = build("gpt2-xl-paper", "aqsgd", num_layers=4,
                                    warmup=True, lr=1e-3, dp_grad_bits=4)
    key = jax.random.PRNGKey(3)
    st, _ = step(state, batch, key)
    assert float(jnp.sum(jnp.abs(st["dp_error"]))) > 0
    _, step2, _, _ = build("gpt2-xl-paper", "aqsgd", num_layers=4,
                           warmup=False, lr=1e-3, dp_grad_bits=4)
    losses = []
    for i in range(4):
        st, met = step2(st, batch, jax.random.fold_in(key, i))
        losses.append(float(met["loss"]))
    assert np.all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses
    print("OK dp_grad_pipeline", losses)


def check_dp_wire_parity():
    """All three DP gradient wires through the REAL pipeline train
    step, from the same initial state and batch stream:

    * ``psum`` vs ``ring`` — bit-identical losses at every step (the
      programs differ only inside the collective; int32 code sums are
      exact in any order);
    * chunked ``ring`` / ``ring-sharded`` (``dp.chunks=2``, the
      double-buffered schedule) — bit-identical losses to their
      monolithic forms at every step (chunking is scheduling only);
    * ``ring`` vs ``ring-sharded`` — bit-identical losses while the
      trajectories coincide (first steps), then tracking at ulp level:
      the sharded program replaces the pjit-level per-leaf AdamW with
      the fused in-shard_map segment update, and XLA fuses the
      surrounding model backward differently — the same documented
      drift class as swapping codec backends (see core/boundary.py),
      NOT codec divergence.  The collective itself is pinned bit-exact
      against ring/psum/sim in dp_grad_worker.py.

    This check also regresses the GSPMD flatten-bucket doubling bug
    (`pipeline.replicate_leaves`): without the replication pin, every
    wire ships a 2x gradient bucket on meshes with model > 1 and the
    sharded trajectory separates immediately and grossly."""
    runs = {}
    for wire, chunks in (("psum", 1), ("ring", 1), ("ring-sharded", 1),
                         ("ring", 2), ("ring-sharded", 2)):
        cfg, step, state, batch = build(
            "gpt2-xl-paper", "aqsgd", num_layers=4, warmup=False,
            lr=1e-3, dp_grad_bits=4, dp_wire=wire, dp_chunks=chunks)
        key = jax.random.PRNGKey(3)
        losses = []
        for i in range(4):
            state, met = step(state, batch, jax.random.fold_in(key, i))
            losses.append(float(met["loss"]))
        runs[wire if chunks == 1 else f"{wire}/K{chunks}"] = losses
    assert runs["psum"] == runs["ring"], (runs["psum"], runs["ring"])
    # the chunked double-buffered schedule is scheduling only: losses
    # bit-identical to the monolithic wires at every step
    assert runs["ring/K2"] == runs["ring"], \
        (runs["ring/K2"], runs["ring"])
    assert runs["ring-sharded/K2"] == runs["ring-sharded"], \
        (runs["ring-sharded/K2"], runs["ring-sharded"])
    # sharded: exact while trajectories coincide, tight thereafter
    assert runs["ring-sharded"][:2] == runs["ring"][:2], \
        (runs["ring-sharded"], runs["ring"])
    np.testing.assert_allclose(runs["ring-sharded"], runs["ring"],
                               rtol=2e-3)
    assert all(np.isfinite(v) for v in runs["ring-sharded"])
    print("OK dp_wire_parity", runs["ring"], runs["ring-sharded"])


def check_dp_wire_fp16():
    """The registry-only fp16 passthrough wire through the REAL
    pipeline train step: `make_dp_grad_wire` resolves it from the wire
    registry with zero trainer special-casing (nothing in
    core/collectives.py knows it exists), and it trains with finite
    decreasing losses that track the codec wires loosely (same
    gradients up to f16 rounding vs 4-bit EF quantization)."""
    cfg, step, state, batch = build(
        "gpt2-xl-paper", "aqsgd", num_layers=4, warmup=False, lr=1e-3,
        dp_grad_bits=4, dp_wire="fp16")
    key = jax.random.PRNGKey(3)
    losses = []
    for i in range(4):
        state, met = step(state, batch, jax.random.fold_in(key, i))
        losses.append(float(met["loss"]))
    assert np.all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses
    # the cast-error feedback state becomes active after one step
    assert float(jnp.sum(jnp.abs(state["dp_error"]))) > 0
    print("OK dp_wire_fp16", losses)


def check_expert_parallel():
    """EP MoE == ZeRO-3 MoE numerically (no-drop capacity), and the
    pipeline still trains."""
    import repro.training.pipeline as PLmod

    def build_ep(moe_mode):
        cfg = get_config("deepseek-moe-16b", smoke=True)
        mesh = make_debug_mesh(2, 2)
        pcfg = PL.PipelineConfig(
            microbatches=2, moe_mode=moe_mode,
            comm=CommConfig.from_legacy(CompressionConfig(mode="fp32")))
        step, meta = PL.make_train_step(
            cfg, pcfg, mesh, AdamWConfig(lr=0.0, warmup_steps=1,
                                         schedule="constant"),
            global_batch=4, seq_len=32, buffer_samples=2)
        params = PL.to_pipeline_params(
            cfg, Mo.init_params(cfg, jax.random.PRNGKey(0)), 2)
        state = {"params": params, "opt": adamw.init_opt_state(params)}
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1),
                                         (2, 2, 32), 0, cfg.vocab_size),
            "targets": jax.random.randint(jax.random.PRNGKey(2),
                                          (2, 2, 32), 0, cfg.vocab_size),
            "mask": jnp.ones((2, 2, 32), jnp.float32),
            "sample_ids": jnp.arange(4, dtype=jnp.int32).reshape(2, 2),
        }
        _, metrics = step(state, batch, jax.random.PRNGKey(3))
        return float(metrics["loss"])

    l_z3 = build_ep("zero3")
    l_ep = build_ep("expert_parallel")
    print("zero3", l_z3, "ep", l_ep)
    np.testing.assert_allclose(l_ep, l_z3, rtol=1e-4)
    print("OK expert_parallel")


if __name__ == "__main__":
    globals()["check_" + sys.argv[1]]()
