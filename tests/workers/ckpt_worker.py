"""Subprocess worker: full-state checkpoint round-trip for every
struct `make_state_structs` emits — params, dense AND segment-sharded
(ZeRO) optimizer moments, the eval_shape-derived ``dp_error`` EF
stack, raw and z-bit (codes/scale) message buffers, and the quantized
opt-state layout — on a 1-D (data=1) and a 2x2 mesh, with both codec
backends.  Every leaf must survive save -> restore bit-identically
(``tobytes`` equality, so bf16/uint8/int32 round through the f32
storage rule exactly).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import tempfile
import shutil
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.comm.config import CommConfig
from repro.configs.base import get_config
from repro.launch.mesh import make_debug_mesh
from repro.optim.adamw import AdamWConfig
from repro.training import pipeline as PL


def _leaf_key(path):
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def materialize(structs):
    """Deterministic per-leaf fill (seeded by the leaf path) so a
    mapping bug between two same-shaped leaves cannot cancel out."""
    def fill(path, s):
        rng = np.random.default_rng(zlib.crc32(_leaf_key(path).encode()))
        dt = np.dtype(s.dtype)
        if dt.kind in "iu":
            a = rng.integers(0, 200, size=s.shape)
        elif dt.kind == "b":
            a = rng.integers(0, 2, size=s.shape).astype(bool)
        else:
            a = rng.standard_normal(s.shape)
        return jnp.asarray(a).astype(s.dtype)
    return jax.tree_util.tree_map_with_path(fill, structs)


def check_roundtrip(state, comm, tag):
    d = tempfile.mkdtemp()
    try:
        ckpt.save_state(d, state, step=9, comm=comm)
        out, body = ckpt.restore_state(
            d, jax.eval_shape(lambda: state), comm=comm)
    finally:
        shutil.rmtree(d)
    assert body["step"] == 9, tag
    want = dict(jax.tree_util.tree_flatten_with_path(state)[0])
    got = dict(jax.tree_util.tree_flatten_with_path(out)[0])
    assert want.keys() == got.keys(), tag
    for p in want:
        a, b = np.asarray(want[p]), np.asarray(got[p])
        assert a.dtype == b.dtype, (tag, _leaf_key(p))
        assert a.tobytes() == b.tobytes(), (tag, _leaf_key(p))


def run_case(data, model, backend, wire, zbits, opt_bits):
    mesh = make_debug_mesh(data, model)
    cfg = get_config("gpt2-xl-paper", smoke=True)
    bk = {"backend": backend}
    comm = CommConfig.from_dict({
        "mode": "aqsgd",
        "fw": {"bits": 4, **bk}, "bw": {"bits": 8, **bk},
        "zbuf": {"bits": zbits, **bk},
        "dp": {"bits": 4, "wire": wire, **bk}, "kv": bk})
    pcfg = PL.PipelineConfig(microbatches=2, comm=comm)
    gb, seq = 4, 32
    _, meta = PL.make_train_step(cfg, pcfg, mesh, AdamWConfig(),
                                 global_batch=gb, seq_len=seq,
                                 buffer_samples=8 // data)
    structs, _, _ = PL.make_state_structs(
        cfg, pcfg, meta, mesh, global_batch=gb, seq_len=seq,
        opt_state_bits=opt_bits)
    state = materialize(structs)
    tag = (f"mesh=({data},{model}) backend={backend} wire={wire} "
           f"zbits={zbits} opt_bits={opt_bits}")
    check_roundtrip(state, comm, tag)
    print("OK", tag)


def main():
    for data, model in ((1, 2), (2, 2)):
        for backend in ("reference", "pallas"):
            # dense opt + raw buffers; ZeRO sharded opt + z-bit
            # buffers; dense-quantized opt state
            run_case(data, model, backend, "ring", zbits=0, opt_bits=0)
            run_case(data, model, backend, "ring-sharded", zbits=4,
                     opt_bits=0)
        run_case(data, model, "reference", "psum", zbits=0, opt_bits=8)
    print("OK ckpt_roundtrip")


if __name__ == "__main__":
    sys.exit(main())
