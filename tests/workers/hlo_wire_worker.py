"""Subprocess worker: measure the DP gradient wires' HLO collective
bytes on a real host mesh.

Compiles all three shard_map collectives — the i32-lane code ``psum``
baseline, the compressed ring, and the ZeRO-sharded reduce-scatter
(the ring stopped at the segment midpoint: no code-sum all-gather at
all) — for one bucket and reports the collective bytes
`launch/hlo_cost.py` counts in the optimized HLO, alongside the
analytic models (`collectives.ring_wire_bytes`, and its
``sharded=True`` mode).  The assertions live in tests/test_hlo_cost.py;
this worker only measures (a subprocess because the host device count
must be set before JAX initializes).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import json

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import collectives as C
from repro.launch.hlo_cost import hlo_cost
from repro.launch.mesh import make_mesh_auto, shard_map

N = 4
ROWS, D = 128, 256


def measure(collective, bits):
    mesh = make_mesh_auto((N,), ("d",))
    spec = P("d")

    def wire_fn(v, err, key):
        mean, new_err = collective(v[0], err[0], "d", bits, key,
                                   stochastic=False,
                                   backend="reference")
        return mean[None], new_err[None]

    fn = jax.jit(shard_map(wire_fn, mesh, (spec, spec, P()),
                           (spec, spec)))
    v = jax.ShapeDtypeStruct((N, ROWS, D), jnp.float32)
    err = jax.ShapeDtypeStruct((N, ROWS, D), jnp.float32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    text = fn.lower(v, err, key).compile().as_text()
    return hlo_cost(text).coll_bytes


def main():
    out = {"n": N, "rows": ROWS, "d": D, "bits": {}}
    for bits in (2, 4, 8):
        out["bits"][str(bits)] = {
            "psum": measure(C.ef_psum_mean_bucket, bits),
            "ring": measure(C.ring_ef_reduce_mean_bucket, bits),
            "sharded": measure(C.ring_ef_reduce_scatter_bucket, bits),
            "model": C.ring_wire_bytes((ROWS, D), bits, n=N),
            "model_sharded": C.ring_wire_bytes((ROWS, D), bits, n=N,
                                               sharded=True),
        }
    print("HLOWIRE " + json.dumps(out))


if __name__ == "__main__":
    main()
