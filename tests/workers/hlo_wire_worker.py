"""Subprocess worker: measure the DP gradient wires' HLO collective
bytes on a real host mesh.

Compiles EVERY wire registered on the dp-grad plane of
`repro.comm.wires` — the i32-lane code ``psum`` baseline, the
compressed ring, the ZeRO-sharded reduce-scatter, the ``fp16``
passthrough, and whatever a later PR registers — for one bucket, and
reports the collective bytes `launch/hlo_cost.py` counts in the
optimized HLO alongside each spec's analytic ``wire_bytes`` model.
Because the wire list is DERIVED from the registry, registering a new
DP wire automatically enrolls it in the byte regression; a wire
cannot land without a pinned byte model (the completeness assertions
live in tests/test_hlo_cost.py; this worker only measures — a
subprocess because the host device count must be set before JAX
initializes).  Chunkable wires are additionally compiled at
``chunks`` in {2, 3, 4} (3 is ragged at seg=32): the chunked
double-buffered schedule must put EXACTLY the monolithic model's
bytes on the wire — K slices of the same payload, not K payloads.

Serving planes ride the same harness: the delta decode hop compiles as
a real collective-permute crossing (collective bytes vs the
fw-activation ``ppermute`` model over the ``(B, 1, d)`` decode shape)
and the quantized KV append compiles to output buffers whose bytes the
``paged`` wire's model must predict (HBM plane — `measure_result_bytes`
instead of collective bytes).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import json

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import wires as W
from repro.core import boundary as Bd
from repro.launch.hlo_cost import (measure_collective_bytes,
                                   measure_result_bytes)
from repro.launch.mesh import make_mesh_auto, shard_map
from repro.serving.kvcache import KVCodec

N = 4
ROWS, D = 128, 256
BITS = (2, 4, 8)
# serving shapes: decode hop (B, 1, d); KV append over one layer store
HOP_B, HOP_D = 8, 256
KV_B, KV_S, KV_HK, KV_HD = 2, 16, 2, 64


def measure(spec, bits, chunks=None):
    mesh = make_mesh_auto((N,), ("d",))
    pspec = P("d")

    def wire_fn(v, err, key):
        kw = {} if chunks is None else {"chunks": chunks}
        out, new_err = spec.collective(v[0], err[0], "d", bits, key,
                                       stochastic=False,
                                       backend="reference", **kw)
        return out[None], new_err[None]

    fn = shard_map(wire_fn, mesh, (pspec, pspec, P()), (pspec, pspec))
    v = jax.ShapeDtypeStruct((N, ROWS, D), jnp.float32)
    err = jax.ShapeDtypeStruct((N, ROWS, D), jnp.float32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return measure_collective_bytes(fn, v, err, key)


def measure_hop(bits):
    """The decode hop as a REAL collective-permute crossing: delta-
    encode on the sender, ship packed codes + scales, accumulate on the
    receiver — collective bytes vs the fw ppermute wire model."""
    mesh = make_mesh_auto((N,), ("s",))

    def hop(h, m):
        packed, scale, m_new = Bd.encode_delta(
            h[0], m[0], bits=bits, stochastic=False, backend="reference")
        perm = [(i, (i + 1) % N) for i in range(N)]
        packed = jax.lax.ppermute(packed, "s", perm)
        scale = jax.lax.ppermute(scale, "s", perm)
        out = Bd.decode_accumulate(packed, scale, m[0], bits=bits,
                                   backend="reference")
        return out[None], m_new[None]

    fn = shard_map(hop, mesh, (P("s"), P("s")), (P("s"), P("s")))
    h = jax.ShapeDtypeStruct((N, HOP_B, 1, HOP_D), jnp.float32)
    m = jax.ShapeDtypeStruct((N, HOP_B, 1, HOP_D), jnp.float32)
    return measure_collective_bytes(fn, h, m)


def measure_kv(bits):
    """One quantize-on-append compile: the output buffers (codes +
    scale stores) are the kv plane's HBM payload."""
    codec = KVCodec(bits=bits, backend="reference")
    store = codec.empty((KV_B, KV_S, KV_HK, KV_HD), jnp.float32)

    def fn(codes, scale, vals, pos):
        out = codec.append({"codes": codes, "scale": scale}, vals, pos)
        return out["codes"], out["scale"]

    specs = (jax.ShapeDtypeStruct(store["codes"].shape, jnp.uint8),
             jax.ShapeDtypeStruct(store["scale"].shape, jnp.float32),
             jax.ShapeDtypeStruct((KV_B, 1, KV_HK, KV_HD), jnp.float32),
             jax.ShapeDtypeStruct((), jnp.int32))
    return measure_result_bytes(fn, *specs)


def main():
    names = W.wire_names("dp-grad")
    out = {"n": N, "rows": ROWS, "d": D, "wires": names, "bits": {},
           "hop": {"b": HOP_B, "d": HOP_D},
           "kv": {"shape": [KV_B, KV_S, KV_HK, KV_HD]}}
    fw = W.get_wire("ppermute", plane="fw-activation")
    kv = W.get_wire("paged", plane="kv-cache")
    for bits in BITS:
        codec = KVCodec(bits=bits)
        out["hop"][str(bits)] = {
            "measured": measure_hop(bits),
            "model": fw.wire_bytes((HOP_B, 1, HOP_D), bits, 1)}
        out["kv"][str(bits)] = {
            "measured": measure_kv(bits),
            "model": kv.wire_bytes(
                codec.grouped_shape((KV_B, KV_S, KV_HK, KV_HD)), bits, 1)}
    out["hop"]["fp32"] = HOP_B * HOP_D * 4
    out["hop"]["fp16"] = HOP_B * HOP_D * 2
    for bits in BITS:
        row = {}
        for name in names:
            spec = W.get_wire(name)
            # every (wire, bits) pair compiles and measures for real —
            # a bits-independent MODEL (fp16) must still match the
            # compiled bytes at every width, or the pin would miss a
            # collective whose realized bytes secretly depend on bits
            row[name] = measure(spec, bits)
            row["model_" + name] = spec.wire_bytes((ROWS, D), bits, N)
        # legacy key aliases kept for the pre-registry regressions
        row["sharded"] = row["ring-sharded"]
        row["model_sharded"] = row["model_ring-sharded"]
        row["model"] = row["model_ring"]
        # chunked schedules of every chunkable wire: the measured HLO
        # collective bytes must stay EXACTLY the monolithic model —
        # chunking moves the same payload in K slices (K=3 is ragged
        # at seg=32).  Keyed separately from the wire list so the
        # registry set-equality pin stays on wire names.
        row["chunked"] = {
            name: {str(k): measure(W.get_wire(name), bits, chunks=k)
                   for k in (2, 3, 4)}
            for name in names if W.get_wire(name).chunkable}
        out["bits"][str(bits)] = row
    print("HLOWIRE " + json.dumps(out))


if __name__ == "__main__":
    main()
