"""Subprocess worker: measure the DP gradient wires' HLO collective
bytes on a real host mesh.

Compiles EVERY wire registered on the dp-grad plane of
`repro.comm.wires` — the i32-lane code ``psum`` baseline, the
compressed ring, the ZeRO-sharded reduce-scatter, the ``fp16``
passthrough, and whatever a later PR registers — for one bucket, and
reports the collective bytes `launch/hlo_cost.py` counts in the
optimized HLO alongside each spec's analytic ``wire_bytes`` model.
Because the wire list is DERIVED from the registry, registering a new
DP wire automatically enrolls it in the byte regression; a wire
cannot land without a pinned byte model (the completeness assertions
live in tests/test_hlo_cost.py; this worker only measures — a
subprocess because the host device count must be set before JAX
initializes).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import json

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import wires as W
from repro.launch.hlo_cost import measure_collective_bytes
from repro.launch.mesh import make_mesh_auto, shard_map

N = 4
ROWS, D = 128, 256
BITS = (2, 4, 8)


def measure(spec, bits):
    mesh = make_mesh_auto((N,), ("d",))
    pspec = P("d")

    def wire_fn(v, err, key):
        out, new_err = spec.collective(v[0], err[0], "d", bits, key,
                                       stochastic=False,
                                       backend="reference")
        return out[None], new_err[None]

    fn = shard_map(wire_fn, mesh, (pspec, pspec, P()), (pspec, pspec))
    v = jax.ShapeDtypeStruct((N, ROWS, D), jnp.float32)
    err = jax.ShapeDtypeStruct((N, ROWS, D), jnp.float32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return measure_collective_bytes(fn, v, err, key)


def main():
    names = W.wire_names("dp-grad")
    out = {"n": N, "rows": ROWS, "d": D, "wires": names, "bits": {}}
    for bits in BITS:
        row = {}
        for name in names:
            spec = W.get_wire(name)
            # every (wire, bits) pair compiles and measures for real —
            # a bits-independent MODEL (fp16) must still match the
            # compiled bytes at every width, or the pin would miss a
            # collective whose realized bytes secretly depend on bits
            row[name] = measure(spec, bits)
            row["model_" + name] = spec.wire_bytes((ROWS, D), bits, N)
        # legacy key aliases kept for the pre-registry regressions
        row["sharded"] = row["ring-sharded"]
        row["model_sharded"] = row["model_ring-sharded"]
        row["model"] = row["model_ring"]
        out["bits"][str(bits)] = row
    print("HLOWIRE " + json.dumps(out))


if __name__ == "__main__":
    main()
