"""Subprocess worker: the compressed DP gradient wires on host devices.

ALL THREE shard_map wires — the i32-lane psum form
(`core.collectives.ef_psum_mean_bucket`), the bandwidth-optimal
compressed ring (`core.collectives.ring_ef_reduce_mean_bucket`: packed
b-bit code segments on rotation ppermutes, fused local
unpack-accumulate, packed code-sum all-gather), and the ZeRO-sharded
reduce-scatter (`core.collectives.ring_ef_reduce_scatter_bucket`: the
same ring stopped at the segment midpoint, each rank decoding only its
owned segment) — must match the single-process simulation
(`core.grad_compress.compress_allreduce` and its sharded extension
`compress_reduce_scatter`) BIT-FOR-BIT given the same base key: the
shared scale is an order-independent f32 max and the code accumulation
is an exact int32 sum, so neither reduction order nor the ring's
segment schedule can introduce drift.  The sharded wire is fed the
same DISTINCT per-rank buckets as the others — the local-gradient
regime — and its owned segments must equal the corresponding rows of
the full allreduce mean.  Checked over multiple steps (the error state
telescopes through the wire), on both codec backends, across ring
sizes {2, 3, 5, 8} (non-power-of-two sizes exercise the ragged last
segment) AND on compound pod x data axes (2x2 and the non-power-of-two
2x3 — the flat row-major rank must drive both the noise keys,
`collectives._fold_axis_index`, and the ring rotation,
`collectives._flat_axis_index`).

The chunked double-buffered schedule (``chunks=K``) rides the same
gate: for K in {1, 2, 4} plus a ragged K (seg % K != 0), the chunked
ring and chunked ring-sharded wires must be BIT-IDENTICAL to their
monolithic forms — means, owned segments, and telescoped error states
over all steps (int32 code sums are exact in any order and the chunk
encoder row-slices the same noise, so chunking is scheduling only).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import collectives as C
from repro.core import grad_compress as GC
from repro.launch.mesh import make_mesh_auto, shard_map

GROUP = 128
# (device shape, axis names, wire axis, full matrix?) — the full
# bits x backend matrix runs on the two canonical meshes; the other
# ring sizes pin the schedule/raggedness with one configuration each.
MESHES = [
    ((2,), ("d",), "d", True),
    ((2, 2), ("p", "d"), ("p", "d"), True),
    ((3,), ("d",), "d", False),
    ((5,), ("d",), "d", False),
    ((8,), ("d",), "d", False),
    ((2, 3), ("p", "d"), ("p", "d"), False),
]


def _trees(step, w):
    ks = jax.random.split(jax.random.PRNGKey(100 + step), w)
    def one(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"w": jax.random.normal(k1, (57, 33)),
                "b": jax.random.normal(k2, (19,)),
                "s": jax.random.normal(k3, (4096, 2)) * 0.3}
    return [one(k) for k in ks]


def run_case(shape, axes, wire_axis, bits, backend, chunk_sweep=True):
    w = int(np.prod(shape))
    mesh = make_mesh_auto(shape, axes)
    lay = GC.bucket_layout(_trees(0, w)[0], GROUP)
    spec = P(axes if len(axes) > 1 else axes[0])

    def make_wire(collective, chunks=None):
        def wire_fn(v, err, key):
            kw = {} if chunks is None else {"chunks": chunks}
            mean, new_err = collective(
                v[0], err[0], wire_axis, bits, key,
                stochastic=True, backend=backend, **kw)
            return mean[None], new_err[None]
        return jax.jit(shard_map(wire_fn, mesh, (spec, spec, P()),
                                 (spec, spec)))

    wire_psum = make_wire(C.ef_psum_mean_bucket)
    wire_ring = make_wire(C.ring_ef_reduce_mean_bucket)
    wire_shrd = make_wire(C.ring_ef_reduce_scatter_bucket)

    seg0 = C.ring_segment_rows(lay.rows, w)
    if chunk_sweep:
        # K in {1, 2, 4} plus one ragged K (seg % K != 0) — K=1 pins
        # the chunked path's degenerate form against the old code
        ragged = next((kk for kk in range(2, seg0 + 1) if seg0 % kk),
                      None)
        Ks = sorted({k for k in (1, 2, 4, ragged)
                     if k is not None and k <= seg0})
    else:
        Ks = []
    wires_ck = {k: (make_wire(C.ring_ef_reduce_mean_bucket, chunks=k),
                    make_wire(C.ring_ef_reduce_scatter_bucket,
                              chunks=k))
                for k in Ks}

    @jax.jit
    def sim(trees, err, key):
        return GC.compress_allreduce(trees, err, bits, key,
                                     stochastic=True, backend=backend,
                                     layout=lay)

    @jax.jit
    def sim_shrd(trees, err, key):
        return GC.compress_reduce_scatter(trees, err, bits, key,
                                          stochastic=True,
                                          backend=backend, layout=lay)

    seg = C.ring_segment_rows(lay.rows, w)
    err_p = jnp.zeros((w, lay.rows, lay.group_d))
    err_r = jnp.zeros((w, lay.rows, lay.group_d))
    err_z = jnp.zeros((w, lay.rows, lay.group_d))
    err_s = jnp.zeros((w, lay.rows, lay.group_d))
    err_zs = jnp.zeros((w, lay.rows, lay.group_d))
    err_ck = {k: (jnp.zeros((w, lay.rows, lay.group_d)),
                  jnp.zeros((w, lay.rows, lay.group_d)))
              for k in Ks}
    for step in range(3):
        trees = _trees(step, w)
        v = jnp.stack([GC.flatten_bucket(t, lay) for t in trees])
        key = jax.random.fold_in(jax.random.PRNGKey(7), step)
        means_p, err_p = wire_psum(v, err_p, key)
        means_r, err_r = wire_ring(v, err_r, key)
        segs_z, err_z = wire_shrd(v, err_z, key)
        mean_s, err_s = sim(trees, err_s, key)
        segs_zs, err_zs = sim_shrd(trees, err_zs, key)
        # all DP ranks hold the same allreduced mean, on both wires
        for r in range(1, w):
            np.testing.assert_array_equal(np.asarray(means_p[0]),
                                          np.asarray(means_p[r]))
            np.testing.assert_array_equal(np.asarray(means_r[0]),
                                          np.asarray(means_r[r]))
        # ring == psum, bit-for-bit, over the WHOLE bucket (both wires
        # see identical codes, sums, and scales — including the
        # zero-pad tail)
        np.testing.assert_array_equal(np.asarray(means_r),
                                      np.asarray(means_p))
        np.testing.assert_array_equal(np.asarray(err_r),
                                      np.asarray(err_p))
        # wire == simulation, bit-for-bit: mean and error state.
        # (Only the live bucket region: the zero-pad tail holds
        # harmless nonzero dequant values on the wire — quantize(0) != 0
        # under a shared scale — and is dropped by unflatten_bucket
        # before touching the optimizer.)
        live_w = np.asarray(means_p[0]).reshape(-1)[:lay.total]
        live_s = np.asarray(GC.flatten_bucket(mean_s, lay)
                            ).reshape(-1)[:lay.total]
        np.testing.assert_array_equal(live_w, live_s)
        np.testing.assert_array_equal(np.asarray(err_p),
                                      np.asarray(err_s))
        # ZeRO-sharded wire: encodes identically (same error state),
        # and each rank's owned segment is bit-equal to the same rows
        # of the full ring/psum mean — fed DISTINCT per-rank buckets,
        # i.e. the local-gradient regime the sharded optimizer runs in
        np.testing.assert_array_equal(np.asarray(err_z),
                                      np.asarray(err_r))
        full = np.asarray(means_r[0])
        sg = np.asarray(segs_z)
        for r in range(w):
            lo, hi = r * seg, min((r + 1) * seg, lay.rows)
            np.testing.assert_array_equal(sg[r, :hi - lo], full[lo:hi])
        # ...and bit-equal to the sharded simulator extension,
        # INCLUDING the zero-scale-decoded pad rows of a ragged last
        # segment
        np.testing.assert_array_equal(sg, np.asarray(segs_zs))
        np.testing.assert_array_equal(np.asarray(err_z),
                                      np.asarray(err_zs))
        # chunked double-buffered schedule: BIT-IDENTICAL to the
        # monolithic wires for every K — means, owned segments, and
        # telescoped error states (the chunked path is scheduling only)
        for k, (wr_k, ws_k) in wires_ck.items():
            er_k, ez_k = err_ck[k]
            means_k, er_k = wr_k(v, er_k, key)
            segs_k, ez_k = ws_k(v, ez_k, key)
            err_ck[k] = (er_k, ez_k)
            np.testing.assert_array_equal(np.asarray(means_k),
                                          np.asarray(means_r))
            np.testing.assert_array_equal(np.asarray(er_k),
                                          np.asarray(err_r))
            np.testing.assert_array_equal(np.asarray(segs_k),
                                          np.asarray(segs_z))
            np.testing.assert_array_equal(np.asarray(ez_k),
                                          np.asarray(err_z))


def main():
    for shape, axes, wire_axis, full in MESHES:
        cases = [(4, "reference"), (4, "pallas"), (8, "reference"),
                 (8, "pallas")] if full else [(4, "reference")]
        for bits, backend in cases:
            # full-matrix meshes sweep chunked Ks at bits=4 only (both
            # backends); single-combo meshes always sweep — bounds
            # compile time without losing ragged-ring K coverage
            run_case(shape, axes, wire_axis, bits, backend,
                     chunk_sweep=(bits == 4 or not full))
            print(f"OK mesh={shape} bits={bits} backend={backend}")
    # one pallas spot-check on a non-power-of-two ring (sw=16 sum pack)
    run_case((3,), ("d",), "d", 8, "pallas")
    print("OK mesh=(3,) bits=8 backend=pallas")
    print("OK dp_grad")


if __name__ == "__main__":
    main()
