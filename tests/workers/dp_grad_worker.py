"""Subprocess worker: the compressed DP gradient wire on host devices.

The shard_map wire (`core.collectives.ef_psum_mean_bucket`: pmax-shared
scale, fused quantize-pack, int32 code psum, fused dequant-mean, carried
error) must match the single-process simulation
(`core.grad_compress.compress_allreduce`) BIT-FOR-BIT given the same
base key: the shared scale is an order-independent f32 max and the code
accumulation is an exact int32 sum, so reduction order cannot introduce
drift.  Checked over multiple steps (the error state telescopes through
the wire), on both codec backends, on a single DP axis (2 ranks) AND on
a compound pod x data axis (2 x 2 ranks — the flat row-major rank must
drive the noise keys, `collectives._fold_axis_index`).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import collectives as C
from repro.core import grad_compress as GC
from repro.launch.mesh import make_mesh_auto, shard_map

GROUP = 128
MESHES = [((2,), ("d",), "d"), ((2, 2), ("p", "d"), ("p", "d"))]


def _trees(step, w):
    ks = jax.random.split(jax.random.PRNGKey(100 + step), w)
    def one(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"w": jax.random.normal(k1, (57, 33)),
                "b": jax.random.normal(k2, (19,)),
                "s": jax.random.normal(k3, (4096, 2)) * 0.3}
    return [one(k) for k in ks]


def run_case(shape, axes, wire_axis, bits, backend):
    w = int(np.prod(shape))
    mesh = make_mesh_auto(shape, axes)
    lay = GC.bucket_layout(_trees(0, w)[0], GROUP)
    spec = P(axes if len(axes) > 1 else axes[0])

    def wire_fn(v, err, key):
        mean, new_err = C.ef_psum_mean_bucket(
            v[0], err[0], wire_axis, bits, key,
            stochastic=True, backend=backend)
        return mean[None], new_err[None]

    wire = jax.jit(shard_map(wire_fn, mesh, (spec, spec, P()),
                             (spec, spec)))

    @jax.jit
    def sim(trees, err, key):
        return GC.compress_allreduce(trees, err, bits, key,
                                     stochastic=True, backend=backend,
                                     layout=lay)

    err_w = jnp.zeros((w, lay.rows, lay.group_d))
    err_s = jnp.zeros((w, lay.rows, lay.group_d))
    for step in range(3):
        trees = _trees(step, w)
        v = jnp.stack([GC.flatten_bucket(t, lay) for t in trees])
        key = jax.random.fold_in(jax.random.PRNGKey(7), step)
        means, err_w = wire(v, err_w, key)
        mean_s, err_s = sim(trees, err_s, key)
        # all DP ranks hold the same allreduced mean
        for r in range(1, w):
            np.testing.assert_array_equal(np.asarray(means[0]),
                                          np.asarray(means[r]))
        # wire == simulation, bit-for-bit: mean and error state.
        # (Only the live bucket region: the zero-pad tail holds
        # harmless nonzero dequant values on the wire — quantize(0) != 0
        # under a shared scale — and is dropped by unflatten_bucket
        # before touching the optimizer.)
        live_w = np.asarray(means[0]).reshape(-1)[:lay.total]
        live_s = np.asarray(GC.flatten_bucket(mean_s, lay)
                            ).reshape(-1)[:lay.total]
        np.testing.assert_array_equal(live_w, live_s)
        np.testing.assert_array_equal(np.asarray(err_w),
                                      np.asarray(err_s))


def main():
    for shape, axes, wire_axis in MESHES:
        for bits in (4, 8):
            for backend in ("reference", "pallas"):
                run_case(shape, axes, wire_axis, bits, backend)
                print(f"OK mesh={shape} bits={bits} backend={backend}")
    print("OK dp_grad")


if __name__ == "__main__":
    main()
