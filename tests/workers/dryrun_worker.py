"""Subprocess worker: reduced-config dry-run on a small in-container mesh.

Proves the full launch path (lower -> compile -> memory/cost analysis ->
roofline extraction) end-to-end without 512 fake devices.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys

import jax
import jax.numpy as jnp

from repro.comm.config import CommConfig
from repro.configs.base import get_config, InputShape
from repro.core.aqsgd import CompressionConfig
from repro.launch import analysis
from repro.launch.mesh import make_debug_mesh
from repro.models import model as Mo
from repro.optim.adamw import AdamWConfig
from repro.serving import decode as Sv
from repro.training import pipeline as PL
from jax.sharding import NamedSharding, PartitionSpec as P


def main():
    mesh = make_debug_mesh(4, 2)
    shape = InputShape("smoke_train", 64, 8, "train")
    for arch in ["gemma2-9b", "deepseek-moe-16b", "mamba2-1.3b"]:
        cfg = get_config(arch, smoke=True)
        n_scan = cfg.num_layers - cfg.first_dense_layers
        if n_scan % 2:
            cfg = cfg.with_(num_layers=cfg.num_layers + 1)
        pcfg = PL.PipelineConfig(
            microbatches=2,
            comm=CommConfig.from_legacy(CompressionConfig(mode="aqsgd")))
        step, meta = PL.make_train_step(
            cfg, pcfg, mesh, AdamWConfig(), global_batch=shape.global_batch,
            seq_len=shape.seq_len, buffer_samples=2)
        state, batch, key = PL.make_state_structs(
            cfg, pcfg, meta, mesh, global_batch=shape.global_batch,
            seq_len=shape.seq_len)
        compiled = step.lower(state, batch, key).compile()
        roof = analysis.analyze_compiled(
            compiled, arch=arch, shape="smoke_train", mesh_desc="4x2",
            chips=8, model_flops=analysis.model_flops_estimate(
                cfg, "train", shape.global_batch, shape.seq_len))
        assert roof.flops_per_device > 0
        assert roof.coll_bytes_per_device > 0
        print("train ok:", arch, roof.bottleneck,
              f"useful={roof.useful_ratio:.2f}")

    # decode path
    for arch in ["gemma2-9b", "zamba2-2.7b"]:
        cfg = get_config(arch, smoke=True).with_(dtype="bfloat16")
        B, S = 8, 128
        params_shape = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if jnp.issubdtype(s.dtype,
                                                        jnp.floating)
                else s.dtype),
            jax.eval_shape(lambda: Mo.init_params(cfg,
                                                  jax.random.PRNGKey(0))))
        cache_shape = jax.eval_shape(
            lambda: Mo.init_caches(cfg, B, S, jnp.bfloat16))
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        fn = Sv.jit_serve_step(cfg, mesh, params_shape, cache_shape, tok)
        compiled = fn.lower(params_shape, cache_shape, tok).compile()
        assert compiled.cost_analysis() is not None
        print("decode ok:", arch)
    print("DRYRUN OK")


if __name__ == "__main__":
    main()
