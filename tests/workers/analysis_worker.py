"""Subprocess worker: prove the HLO collective auditor FIRES.

Builds two deliberately-broken variants of the registered ``fp16`` DP
wire and audits them on the real 4-device host ring (a subprocess
because the device count must be set before JAX initializes):

* ``broken-fp16`` — the wire's collective additionally smuggles an
  f32 ``psum`` of the error carry that its manifest does not declare:
  the audit diff must name the unexpected all-reduce (and, at a
  compressed width, call out the PR-4 f32-on-a-compressed-path bug
  class).
* ``naked-fp16`` — the same wire with its ``expected_collectives``
  manifest stripped: a collective wire with no manifest must fail the
  audit outright.

Prints ``ANALYSIS <json>`` with both `WireAudit` dicts for
tests/test_analysis.py.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import dataclasses
import json

import jax

from repro.analysis.collectives import audit_wire
from repro.comm import wires as W


def main():
    base = W.get_wire("fp16")

    def smuggled(v, err, axis, bits, key, **kw):
        out, new_err = base.collective(v, err, axis, bits, key, **kw)
        # the seeded violation: an f32 all-reduce the manifest never
        # declared (values irrelevant — only the compile is audited)
        return out + jax.lax.psum(err, axis), new_err

    broken = dataclasses.replace(base, name="broken-fp16",
                                 collective=smuggled)
    naked = dataclasses.replace(base, name="naked-fp16",
                                expected_collectives=None)
    out = {"broken": audit_wire(broken, 2).to_dict(),
           "naked": audit_wire(naked, 2).to_dict()}
    print("ANALYSIS " + json.dumps(out))


if __name__ == "__main__":
    main()
