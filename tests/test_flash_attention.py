"""Flash attention (custom_vjp, O(S) residuals): values AND gradients
must match the dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L

KEY = jax.random.PRNGKey(0)


def _dense(q, k, v, q_pos, k_pos, window, causal=True, cap=0.0):
    hd = q.shape[-1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
    s = L.softcap(s, cap)
    vis = jnp.ones(s.shape, bool)
    if causal:
        vis &= k_pos[:, None, None, :] <= q_pos[:, None, :, None]
    vis &= k_pos[:, None, None, :] > (q_pos[:, None, :, None] - window)
    s = jnp.where(vis, s, -1e9)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))


def _setup(b=2, s=48, h=3, hd=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    return q, k, v, pos


@pytest.mark.parametrize("window,cap,causal,block_k", [
    (10 ** 9, 0.0, True, 16),
    (11, 0.0, True, 8),
    (10 ** 9, 5.0, True, 16),
    (10 ** 9, 0.0, False, 64),
    (7, 3.0, True, 32),
])
def test_flash_values_match_dense(window, cap, causal, block_k):
    q, k, v, pos = _setup()
    out = L.flash_attention(q, k, v, q_pos=pos, k_pos=pos, window=window,
                            causal=causal, attn_softcap=cap,
                            block_k=block_k)
    ref = _dense(q, k, v, pos, pos, window, causal, cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window,cap,block_k", [
    (10 ** 9, 0.0, 16),
    (11, 0.0, 8),
    (10 ** 9, 5.0, 16),
    (9, 4.0, 32),
])
def test_flash_grads_match_dense(window, cap, block_k):
    q, k, v, pos = _setup(s=40)

    def loss_flash(q, k, v):
        o = L.flash_attention(q, k, v, q_pos=pos, k_pos=pos, window=window,
                              attn_softcap=cap, block_k=block_k)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)) * 0.3)

    def loss_dense(q, k, v):
        o = _dense(q, k, v, pos, pos, window, True, cap)
        return jnp.sum(jnp.sin(o) * 0.3)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gd, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4,
                                   err_msg=f"d{name}")


def test_flash_traced_window_in_scan():
    """window as a traced per-layer scalar (the pipeline's usage)."""
    q, k, v, pos = _setup(s=32)
    windows = jnp.array([5, 10 ** 9], jnp.int32)

    def f(q):
        def body(c, w):
            o = L.flash_attention(c, k, v, q_pos=pos, k_pos=pos, window=w,
                                  block_k=16)
            return o, None
        c, _ = jax.lax.scan(body, q, windows)
        return jnp.sum(c)

    g = jax.grad(f)(q)
    assert np.all(np.isfinite(np.asarray(g)))
