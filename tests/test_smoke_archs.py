"""Per-arch smoke tests: reduced configs, one forward + one train step on
CPU; asserts output shapes and absence of NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHS, get_config
from repro.models import model as Mo

B, S = 2, 32


def make_batch(cfg, key):
    n_text = S - (cfg.num_patches or 0)
    batch = {
        "tokens": jax.random.randint(key, (B, n_text), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (B, n_text), 0, cfg.vocab_size),
        "mask": jnp.ones((B, n_text), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model)) * 0.02
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = Mo.init_params(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(
        lambda p, b: Mo.loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0.0
    # a plausible initial LM loss: within a few nats of log(V)
    assert float(metrics["ce"]) < np.log(cfg.vocab_size) + 3.0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss(arch):
    """One SGD step on a fixed batch must not blow up, and several steps
    must reduce the loss on that batch (overfit sanity)."""
    cfg = get_config(arch, smoke=True)
    params = Mo.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(p):
        (l, _), g = jax.value_and_grad(
            lambda q: Mo.loss_fn(q, cfg, batch), has_aux=True)(p)
        p = jax.tree.map(lambda w, d: w - 0.05 * d, p, g)
        return p, l

    losses = []
    for _ in range(5):
        params, l = step(params)
        losses.append(float(l))
    assert np.all(np.isfinite(losses)), (arch, losses)
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ["gemma2-9b", "mamba2-1.3b",
                                  "zamba2-2.7b", "deepseek-moe-16b"])
def test_stage_split_matches_monolithic(arch):
    """trunk split into 2 stages with identity boundary == 1 stage."""
    cfg = get_config(arch, smoke=True)
    params = Mo.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    l1, _ = Mo.loss_fn(params, cfg, batch, num_stages=1)
    l2, _ = Mo.loss_fn(params, cfg, batch, num_stages=2,
                       boundary_fn=lambda st, h, i: (st, h))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
