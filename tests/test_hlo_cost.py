"""Validate the loop-aware HLO cost parser against ground truth:
fully-unrolled compiles (where XLA's own cost_analysis is exact) —
and pin the compressed ring collectives' wire bytes against the
analytic models and the i32-psum baseline (the perf claims)."""
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantization as Q
from repro.launch.hlo_cost import hlo_cost
from repro.launch.mesh import make_mesh_auto, shard_map
from test_distributed import run_worker


def _compiled(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_flops_match_unrolled_and_analytic():
    def make(unroll):
        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            c, _ = jax.lax.scan(body, x, ws, unroll=unroll)
            return c
        return f

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    expected = 10 * 2 * 128 * 256 * 256
    c_scan = _compiled(make(1), x, ws)
    c_unrl = _compiled(make(True), x, ws)
    f_scan = hlo_cost(c_scan.as_text()).flops
    f_unrl = hlo_cost(c_unrl.as_text()).flops
    assert f_scan == pytest.approx(expected, rel=1e-6)
    assert f_unrl == pytest.approx(expected, rel=1e-6)
    # and XLA's raw number undercounts the scan by the trip count
    ca = c_scan.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert ca["flops"] * 9 < f_scan


def test_nested_scan_trip_products():
    def f(x, ws):
        def outer(c, _):
            def inner(ci, w):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, ws)
            return c, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)
    expected = 5 * 3 * 2 * 64 ** 3
    got = hlo_cost(_compiled(f, x, ws).as_text()).flops
    assert got == pytest.approx(expected, rel=1e-6)


def test_grad_flops_roughly_triple():
    def loss(w, x):
        return jnp.sum(jnp.tanh(x @ w) ** 2)

    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    fwd = hlo_cost(_compiled(loss, w, x).as_text()).flops
    bwd = hlo_cost(_compiled(jax.grad(loss), w, x).as_text()).flops
    assert 2.0 <= bwd / fwd <= 3.6     # fwd + two matmul transposes


def test_collective_bytes_counted_with_trips():
    mesh = make_mesh_auto((1,), ("x",))

    def f(x):
        def body(c, _):
            return jax.lax.psum(c, "x"), None
        c, _ = jax.lax.scan(body, x, None, length=4)
        return c

    smapped = shard_map(f, mesh, jax.sharding.PartitionSpec("x"),
                        jax.sharding.PartitionSpec("x"))
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    cost = hlo_cost(jax.jit(smapped).lower(x).compile().as_text())
    # 4 iterations x (8*128*4) bytes; single-device all-reduce may be
    # elided -> accept either exact or zero-with-note
    expected = 4 * 8 * 128 * 4
    assert cost.coll["all-reduce"] in (0.0, pytest.approx(expected))


@functools.lru_cache(maxsize=1)
def _wire_measurements():
    """One hlo_wire_worker run shared by both wire regressions (the
    subprocess compiles all three collectives at three widths — the
    slowest part of this module)."""
    stdout = run_worker("hlo_wire_worker.py", "run", timeout=900)
    line = [ln for ln in stdout.splitlines()
            if ln.startswith("HLOWIRE ")][0]
    return json.loads(line[len("HLOWIRE "):])


def test_registry_wire_bytes_models_are_exact():
    """EVERY wire registered on the dp-grad plane must have a
    `WireSpec.wire_bytes` model that matches the collective bytes of
    its compiled HLO EXACTLY, at every tested width.  The worker
    derives its wire list from the registry, and this test pins that
    list against the registry too — so registering a new DP wire
    auto-enrolls it here, and a wire cannot land without an exact byte
    model (the fp16 passthrough's 2-byte lanes included)."""
    from repro.comm import wires as W
    out = _wire_measurements()
    assert set(out["wires"]) == set(W.wire_names("dp-grad"))
    for bits in (2, 4, 8):
        row = out["bits"][str(bits)]
        for name in out["wires"]:
            assert row[name] == row["model_" + name], (bits, name, row)


def test_chunked_wire_bytes_equal_monolithic_model():
    """The chunked double-buffered schedule must not change what goes
    on the wire: for every chunkable DP wire, at every tested K
    (including the ragged K=3 at seg=32) and every width, the
    HLO-measured collective bytes of the ``chunks=K`` compile equal
    the MONOLITHIC ``wire_bytes`` model EXACTLY — K slices of the same
    payload, not K payloads, and no hidden padding bytes."""
    from repro.comm import wires as W
    out = _wire_measurements()
    chunkable = [n for n in out["wires"]
                 if W.get_wire(n, plane="dp-grad").chunkable]
    assert chunkable == ["ring", "ring-sharded"]
    for bits in (2, 4, 8):
        row = out["bits"][str(bits)]
        assert set(row["chunked"]) == set(chunkable), row["chunked"]
        for name in chunkable:
            for k in ("2", "3", "4"):
                assert row["chunked"][name][k] == \
                    row["model_" + name], (bits, name, k, row)


def test_fp16_wire_bytes_between_sharded_and_psum():
    """The fp16 passthrough ships exactly rows*d*2 bytes — half the
    psum baseline, independent of the bits knob — and the b-bit codec
    wires stay below it at low widths (the whole point of the codec)."""
    out = _wire_measurements()
    rows, d = out["rows"], out["d"]
    for bits in (2, 4):
        row = out["bits"][str(bits)]
        assert row["fp16"] == rows * d * 2, row
        assert row["ring"] < row["fp16"] < row["psum"], (bits, row)


def test_sharded_wire_collective_bytes_regression():
    """The ZeRO-sharded wire (`ring_ef_reduce_scatter_bucket`) stops at
    the reduce-scatter midpoint, so its HLO collective bytes must
    (a) match `collectives.ring_wire_bytes(..., sharded=True)` EXACTLY
    — only the n-1 packed b-bit segment hops plus the f32 scale pmax —
    and (b) be STRICTLY fewer than the full ring's at every tested b
    (the all-gather of packed code sums vanishes entirely)."""
    out = _wire_measurements()
    n, rows, d = out["n"], out["rows"], out["d"]
    seg = -(-rows // n)
    for bits in (2, 4, 8):
        row = out["bits"][str(bits)]
        assert row["sharded"] == row["model_sharded"], (bits, row)
        # exactly the reduce-scatter half: packed payload + scale pmax
        assert row["model_sharded"] == \
            (n - 1) * seg * Q.packed_width(d, bits) + rows * 4, \
            (bits, row)
        assert row["sharded"] < row["ring"], (bits, row)
        assert row["sharded"] < row["psum"], (bits, row)


def test_ring_wire_collective_bytes_regression():
    """The compressed ring collective must genuinely ship the b-bit
    payload: its HLO collective bytes must (a) match the analytic model
    `collectives.ring_wire_bytes` EXACTLY, and (b) stay at the b-bit
    payload level relative to the i32-psum baseline — <= b/32 of the
    baseline plus the exactness overhead (the packed code-sum
    all-gather at b + ceil(log2 n) bits, and the f32 scale pmax both
    wires pay).  Compiled on a real 4-host-device mesh in a subprocess
    (device count must precede JAX init)."""
    out = _wire_measurements()
    n, rows, d = out["n"], out["rows"], out["d"]
    seg = -(-rows // n)
    scale_bytes = rows * 4
    for bits in (2, 4, 8):
        row = out["bits"][str(bits)]
        # the model is exact — wire accounting in the benchmarks reports
        # the same bytes the compiled program ships
        assert row["ring"] == row["model"], (bits, row)
        # the reduce-scatter half is exactly the b-bit packed payload
        sum_overhead = (n - 1) * seg * Q.sum_packed_width(d, bits, n)
        assert row["ring"] <= row["psum"] * bits / 32.0 \
            + sum_overhead + scale_bytes, (bits, row)
        # and the ring is a strict win over the i32 psum at every width
        assert row["ring"] < row["psum"], (bits, row)


def test_serving_hop_wire_bytes_pinned():
    """The delta decode hop — compiled as a real collective-permute
    crossing (encode_delta -> ppermute codes+scales ->
    decode_accumulate) — must ship EXACTLY the fw-activation ppermute
    wire's modeled bytes over the (B, 1, d) decode shape, and stay
    STRICTLY below the fp16 (and fp32) passthrough hop at every width:
    the serving-plane acceptance gate."""
    out = _wire_measurements()
    hop = out["hop"]
    for bits in (2, 4, 8):
        row = hop[str(bits)]
        assert row["measured"] == row["model"], (bits, row)
        assert row["model"] < hop["fp16"] < hop["fp32"], (bits, hop)


def test_serving_kv_bytes_pinned():
    """The quantized KV append's compiled output buffers (codes + group
    scales — the kv plane's HBM payload) must match the registered
    ``paged`` wire's byte model EXACTLY, and undercut the raw-f32 cache
    at every width.  Enrolment mirrors the DP wires: the worker derives
    the model from the registry, so the kv plane cannot drift from its
    pinned claim."""
    import numpy as np
    out = _wire_measurements()
    kv = out["kv"]
    raw = int(np.prod(kv["shape"])) * 4
    for bits in (2, 4, 8):
        row = kv[str(bits)]
        assert row["measured"] == row["model"], (bits, row)
        assert row["model"] < raw, (bits, row, raw)


def test_every_plane_enrolled_in_byte_regression():
    """Registry completeness: every plane in `repro.comm.wires.PLANES`
    — kv-cache included — has at least one registered wire, and every
    wire of every plane is covered by a byte measurement in THIS
    module's worker output: dp-grad wires by name, the fw/bw ppermute
    pair by the hop crossing, the z-buffer/kv-cache HBM wires by the
    result-bytes compile.  A new plane cannot land unmeasured."""
    from repro.comm import wires as W
    out = _wire_measurements()
    covered = {
        "dp-grad": set(out["wires"]),
        # the hop crossing compiles the ppermute codec both directions
        "fw-activation": {"ppermute"} if "hop" in out else set(),
        "bw-gradient": {"ppermute"} if "hop" in out else set(),
        # HBM planes: z-buffer shares the codec model the hop pins; the
        # kv append is measured directly
        "z-buffer": {"hbm"} if "hop" in out else set(),
        "kv-cache": {"paged"} if "kv" in out else set(),
    }
    for plane in W.PLANES:
        names = set(W.wire_names(plane))
        assert names, plane
        assert names <= covered.get(plane, set()), (plane, names)
