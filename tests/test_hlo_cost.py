"""Validate the loop-aware HLO cost parser against ground truth:
fully-unrolled compiles (where XLA's own cost_analysis is exact)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import hlo_cost
from repro.launch.mesh import make_mesh_auto, shard_map


def _compiled(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_flops_match_unrolled_and_analytic():
    def make(unroll):
        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            c, _ = jax.lax.scan(body, x, ws, unroll=unroll)
            return c
        return f

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    expected = 10 * 2 * 128 * 256 * 256
    c_scan = _compiled(make(1), x, ws)
    c_unrl = _compiled(make(True), x, ws)
    f_scan = hlo_cost(c_scan.as_text()).flops
    f_unrl = hlo_cost(c_unrl.as_text()).flops
    assert f_scan == pytest.approx(expected, rel=1e-6)
    assert f_unrl == pytest.approx(expected, rel=1e-6)
    # and XLA's raw number undercounts the scan by the trip count
    ca = c_scan.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert ca["flops"] * 9 < f_scan


def test_nested_scan_trip_products():
    def f(x, ws):
        def outer(c, _):
            def inner(ci, w):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, ws)
            return c, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)
    expected = 5 * 3 * 2 * 64 ** 3
    got = hlo_cost(_compiled(f, x, ws).as_text()).flops
    assert got == pytest.approx(expected, rel=1e-6)


def test_grad_flops_roughly_triple():
    def loss(w, x):
        return jnp.sum(jnp.tanh(x @ w) ** 2)

    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    fwd = hlo_cost(_compiled(loss, w, x).as_text()).flops
    bwd = hlo_cost(_compiled(jax.grad(loss), w, x).as_text()).flops
    assert 2.0 <= bwd / fwd <= 3.6     # fwd + two matmul transposes


def test_collective_bytes_counted_with_trips():
    mesh = make_mesh_auto((1,), ("x",))

    def f(x):
        def body(c, _):
            return jax.lax.psum(c, "x"), None
        c, _ = jax.lax.scan(body, x, None, length=4)
        return c

    smapped = shard_map(f, mesh, jax.sharding.PartitionSpec("x"),
                        jax.sharding.PartitionSpec("x"))
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    cost = hlo_cost(jax.jit(smapped).lower(x).compile().as_text())
    # 4 iterations x (8*128*4) bytes; single-device all-reduce may be
    # elided -> accept either exact or zero-with-note
    expected = 4 * 8 * 128 * 4
    assert cost.coll["all-reduce"] in (0.0, pytest.approx(expected))
