"""The docs gate (tools/check_docs.py) must stay green: relative
markdown links in README/ROADMAP/docs resolve, and every public
function/class/module in core/ and kernels/ carries a docstring.  CI
runs the same script in the lint job; this test keeps it honest
in-container."""
import importlib.util
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _load():
    spec = importlib.util.spec_from_file_location(
        "check_docs", ROOT / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_markdown_links_resolve():
    assert _load().check_links() == []


def test_core_and_kernels_docstrings():
    assert _load().check_docstrings() == []


def test_env_knobs_documented():
    assert _load().check_env_knobs() == []


def test_gate_aggregates_all_sections(capsys):
    """main() runs every section to completion and exits 0 only when
    all of them are clean (no first-error abort)."""
    assert _load().main() == 0
    out = capsys.readouterr().out
    for section in ("links", "docstrings", "env-knobs"):
        assert f"docs gate [{section}]:" in out
