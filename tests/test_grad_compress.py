"""The DP gradient wire: bucketed error-feedback compression contract.

Mirrors tests/test_boundary_parity.py for the gradient path: the
reference and Pallas backends of the bucketed codec
(`core.grad_compress` + the shared-scale ops in `core.boundary`) must
produce IDENTICAL bits under jit — packed payloads, int32 code sums,
mean gradients, and carried error states.  On top of the parity
contract, the error-feedback algebra itself is pinned:

* telescoping — over T steps, the emitted quantized gradients plus the
  final carried error reconstruct the exact gradient sum (QuantizedAdam
  / Tang et al. 2021's defining invariant: compression error never
  accumulates, it is *deferred*);
* unbiasedness — stochastic rounding through the fused codec is
  mean-zero over many trials (Thm 3.1's requirement on Q);
* bucketing — leaves with small trailing dims are grouped along the
  flattened bucket, never per-row with degenerate scale groups (the
  pre-bucketing `compress_gradients` reshaping bug).

The convergence regression at the bottom (slow tier, nightly) pins the
Fig. 5a claim: AQ-SGD fw3/bw6 + 4-bit error-feedback gradient
compression tracks FP32 where DirectQ + the same gradient wire drifts.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import boundary as B
from repro.core import grad_compress as GC

BITS = [2, 4, 8]
KEY = jax.random.PRNGKey(0)
GROUP = 128


def _tree(seed=0, scale=1.0):
    """A gradient-tree stand-in with awkward shapes: a small-last-dim
    leaf (the old per-row-degenerate case), a vector, a bf16 leaf."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {
        "wide": jax.random.normal(ks[0], (4096, 2)) * scale,
        "bias": jax.random.normal(ks[1], (11,)) * scale,
        "emb": (jax.random.normal(ks[2], (13, 17)) * scale
                ).astype(jnp.bfloat16),
        "blk": jax.random.normal(ks[3], (3, 5, 7)) * scale,
    }


# ---------------------------------------------------------------------------
# bucket layout
# ---------------------------------------------------------------------------

def test_flatten_unflatten_roundtrip_bit_exact():
    tree = _tree()
    lay = GC.bucket_layout(tree, GROUP)
    total = sum(int(np.prod(v.shape)) for v in tree.values())
    assert lay.total == total
    assert lay.rows * lay.group_d == total + lay.pad
    v = GC.flatten_bucket(tree, lay)
    assert v.shape == (lay.rows, GROUP) and v.dtype == jnp.float32
    # padded tail is zeros (padded lanes are dead weight on the wire,
    # but must never perturb scales beyond the real data's absmax)
    flat = np.asarray(v).reshape(-1)
    assert not lay.pad or np.all(flat[total:] == 0)
    back = GC.unflatten_bucket(v, lay, tree)
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(
            np.asarray(tree[k].astype(jnp.float32)),
            np.asarray(back[k].astype(jnp.float32)))


def test_small_last_dim_leaf_groups_along_bucket():
    """Regression for the pre-bucketing reshaping bug: a (4096, 2) leaf
    used to quantize per-row — 4096 degenerate 2-element scale groups,
    one f32 scale per 2 codes (scale bytes 4x the 4-bit payload).  The
    bucketed layout groups along the flattened vector instead."""
    tree = {"w": jnp.zeros((4096, 2))}
    lay = GC.bucket_layout(tree, 512)
    assert lay.rows == 16                       # 8192 / 512, not 4096 rows
    wire = GC.grad_wire_bytes(tree, 4)
    payload = 8192 // 2                         # 4-bit packed
    old_scale_bytes = 4096 * 4                  # per-row scales (the bug)
    new_scale_bytes = wire - payload
    assert new_scale_bytes < old_scale_bytes / 100
    assert new_scale_bytes < payload / 4        # scales amortized away


# ---------------------------------------------------------------------------
# error-feedback invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("stochastic", [False, True])
def test_error_feedback_telescopes(bits, stochastic):
    """v_t = g_t + e_{t-1}, q_t = v_t - e_t  =>  Σ q_t + e_T = Σ g_t:
    the carried error telescopes, so nothing is ever lost — only
    deferred.  Checked through the full bucketed fused codec."""
    tree = _tree(seed=1)
    lay = GC.bucket_layout(tree, GROUP)
    err = GC.init_error_state(tree, GROUP)
    q_sum = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), tree)
    g_sum = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), tree)
    key = jax.random.PRNGKey(2)
    for t in range(5):
        g = _tree(seed=10 + t)
        q, err = GC.compress_gradients(g, err, bits,
                                       jax.random.fold_in(key, t),
                                       stochastic=stochastic, layout=lay)
        q_sum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                             q_sum, q)
        g_sum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                             g_sum, g)
    recon = jax.tree.map(jnp.add, q_sum,
                         GC.unflatten_bucket(err, lay, g_sum))
    for k in tree:
        # bf16 leaves round-trip through their storage dtype each step,
        # so the telescope holds to bf16 resolution there
        tol = 0.1 if tree[k].dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(np.asarray(recon[k]),
                                   np.asarray(g_sum[k]),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("bits", [2, 4])
def test_stochastic_qdq_unbiased_10k_trials(bits):
    """E[Q(x)] = x for stochastic rounding on the shared-scale grid,
    estimated over 10k independent draws through the fused codec."""
    n_trials = 10_000
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 64))
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True),
                        1e-12)

    @jax.jit
    @jax.vmap
    def one(key):
        packed = B.encode_with_scale(x, scale, bits=bits, stochastic=True,
                                     key=key, backend="reference")
        return B.decode(packed, scale, bits=bits, d=x.shape[-1])

    qs = one(jax.random.split(jax.random.PRNGKey(6), n_trials))
    est = np.mean(np.asarray(qs), axis=0)
    cell = 2.0 * np.asarray(scale) / ((1 << bits) - 1)
    # per-element stderr of the mean is <= cell / sqrt(4 * n_trials);
    # 5 sigma over 256 elements keeps the false-positive rate ~1e-4
    bound = 5.0 * cell / (2.0 * np.sqrt(n_trials))
    err = np.abs(est - np.asarray(x))
    assert np.max(err / bound) < 1.0, float(np.max(err / bound))


# ---------------------------------------------------------------------------
# reference <-> pallas bit-identity (the backend contract, under jit)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("bits", "stoch", "backend"))
def _codec(v, s, key, *, bits, stoch, backend):
    packed = B.encode_with_scale(v, s, bits=bits, stochastic=stoch,
                                 key=key, backend=backend)
    codes = B.decode_codes(packed, bits=bits, d=v.shape[-1],
                           backend=backend)
    mean = B.decode_sum_mean(codes * 3, s, bits=bits, n=3, backend=backend)
    return packed, codes, mean


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("stoch", [False, True])
def test_bucketed_codec_bit_identical(bits, stoch):
    """Shared-scale sender, code-domain accumulator, and sum->mean
    receiver: all bit-equal across backends — including an all-zero row
    (raw zero scale), which both backends must clamp identically."""
    v = jax.random.normal(jax.random.PRNGKey(7), (37, 256))
    v = v.at[5].set(0.0)
    s = 1.17 * jnp.max(jnp.abs(v), axis=-1, keepdims=True)
    ref = _codec(v, s, KEY, bits=bits, stoch=stoch, backend="reference")
    pal = _codec(v, s, KEY, bits=bits, stoch=stoch, backend="pallas")
    for name, a, b in zip(("packed", "codes", "mean"), ref, pal):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


@functools.partial(jax.jit, static_argnames=("bits", "stoch", "backend"))
def _ring_codec(v, s, key, *, bits, stoch, backend):
    """The ring wire's op chain: fused pack+codes encode, fused
    unpack-accumulate, code-sum pack/unpack, sum->mean."""
    packed, codes = B.encode_codes_with_scale(
        v, s, bits=bits, stochastic=stoch, key=key, pack=True,
        backend=backend)
    acc = B.accumulate_codes(packed, codes * 2, bits=bits, backend=backend)
    ps = B.pack_sums(acc, bits=bits, n=3, backend=backend)
    total = B.unpack_sums(ps, bits=bits, n=3, d=v.shape[-1],
                          backend=backend)
    mean = B.decode_sum_mean(total, s, bits=bits, n=3, backend=backend)
    return packed, codes, acc, ps, total, mean


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("stoch", [False, True])
def test_ring_codec_bit_identical(bits, stoch):
    """The ring's whole op chain — codes-only encode (with packed
    payload), unpack-accumulate, code-sum pack/unpack, sum->mean — is
    bit-equal across backends under jit, including an all-zero row."""
    v = jax.random.normal(jax.random.PRNGKey(9), (37, 256))
    v = v.at[5].set(0.0)
    s = 1.17 * jnp.max(jnp.abs(v), axis=-1, keepdims=True)
    r = _ring_codec(v, s, KEY, bits=bits, stoch=stoch,
                    backend="reference")
    p = _ring_codec(v, s, KEY, bits=bits, stoch=stoch, backend="pallas")
    names = ("packed", "codes", "acc", "packed_sums", "total", "mean")
    for name, a, b in zip(names, r, p):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
    # the accumulate path reproduces the exact code sum: acc == 3*codes
    np.testing.assert_array_equal(np.asarray(r[2]), 3 * np.asarray(r[1]))
    np.testing.assert_array_equal(np.asarray(r[4]), np.asarray(r[2]))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("stoch", [False, True])
def test_compress_allreduce_bit_identical_across_backends(bits, stoch):
    """The full n-worker bucketed allreduce — mean tree AND carried
    errors — is backend-independent bit-for-bit."""
    trees = [_tree(seed=20 + i) for i in range(3)]
    lay = GC.bucket_layout(trees[0], GROUP)
    err0 = jnp.stack([GC.init_error_state(trees[0], GROUP)] * 3)

    @functools.partial(jax.jit, static_argnames=("backend",))
    def run(err, key, *, backend):
        return GC.compress_allreduce(trees, err, bits, key,
                                     stochastic=stoch, backend=backend,
                                     layout=lay)
    m_r, e_r = run(err0, KEY, backend="reference")
    m_p, e_p = run(err0, KEY, backend="pallas")
    np.testing.assert_array_equal(np.asarray(e_r), np.asarray(e_p))
    for k in m_r:
        np.testing.assert_array_equal(
            np.asarray(m_r[k].astype(jnp.float32)),
            np.asarray(m_p[k].astype(jnp.float32)), err_msg=k)


@pytest.mark.parametrize("n", [2, 3, 5])
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_compress_reduce_scatter_matches_allreduce(n, backend):
    """The ZeRO-sharded sim extension: `compress_reduce_scatter`'s
    owned segments must be BIT-EQUAL to the corresponding rows of
    `compress_allreduce`'s full mean (same codes, same int32 segment
    sums), its error states identical, and the zero-scale pad rows of
    a ragged last segment must decode to (sign-preserving) zeros.
    n=3/5 exercise ragged segments.  (All-f32 trees: the allreduce
    returns a TREE, so its bf16 leaves would round before this
    comparison re-flattens them, while the sharded form returns the
    raw f32 bucket — the bf16 round-trip is covered by the backend
    parity tests above.)"""
    bits = 4
    trees = [jax.tree.map(lambda a: a.astype(jnp.float32),
                          _tree(seed=40 + i)) for i in range(n)]
    lay = GC.bucket_layout(trees[0], GROUP)
    err0 = jnp.stack([GC.init_error_state(trees[0], GROUP)] * n)

    @functools.partial(jax.jit, static_argnames=())
    def run(err, key):
        full = GC.compress_allreduce(trees, err, bits, key,
                                     stochastic=True, backend=backend,
                                     layout=lay)
        shrd = GC.compress_reduce_scatter(trees, err, bits, key,
                                          stochastic=True,
                                          backend=backend, layout=lay)
        return full, shrd
    (mean, e_full), (segs, e_shrd) = run(err0, KEY)
    np.testing.assert_array_equal(np.asarray(e_full),
                                  np.asarray(e_shrd))
    seg = segs.shape[1]
    assert seg == -(-lay.rows // n)
    # live region only: the bucket's zero-pad TAIL (beyond lay.total)
    # holds harmless nonzero dequant values on the sharded bucket —
    # quantize(0) != 0 under a shared scale — which the allreduce tree
    # round-trip already dropped; both drop it before parameters.
    flat_live = np.asarray(GC.flatten_bucket(mean, lay)
                           ).reshape(-1)[:lay.total]
    sg_live = np.asarray(segs).reshape(-1)[:lay.total]
    np.testing.assert_array_equal(sg_live, flat_live)
    pad = seg * n - lay.rows
    if pad:
        # fully-padded rows (beyond lay.rows) decode against a ZERO
        # scale: sign-preserving zeros
        np.testing.assert_array_equal(
            np.abs(np.asarray(segs)[-1, seg - pad:]),
            np.zeros((pad, lay.group_d)))


def test_sim_zero_sharded_training_parity():
    """The simulated trainer's ZeRO mode (``dp_sharded=True``:
    `compress_reduce_scatter` + segment-owner `apply_bucket_updates` +
    parameter reassembly) tracks the allreduce + per-leaf AdamW path on
    DISTINCT per-worker gradients: bit-identical losses while the
    trajectories coincide, ulp-level tracking after (the two jitted
    programs fuse the model backward differently — the documented
    cross-program drift class of core/boundary.py, not codec or
    optimizer divergence: `apply_bucket_updates` is pinned elementwise
    bit-identical to `apply_updates` below)."""
    from repro.comm import CommConfig
    from repro.configs.base import get_config
    from repro.core.aqsgd import CompressionConfig
    from repro.data.pipeline import Dataset, DatasetConfig
    from repro.training import simulated as sim
    from repro.optim.adamw import AdamWConfig

    cfg = get_config("gpt2-xl-paper", smoke=True).with_(num_layers=2)
    dc = DatasetConfig(num_samples=8, seq_len=16,
                       vocab_size=cfg.vocab_size, kind="synthetic-lm")
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    out = {}
    for sh in (False, True):
        tcfg = sim.SimTrainConfig(
            num_stages=2,
            comm=CommConfig.from_legacy(
                CompressionConfig(mode="aqsgd", fw_bits=4, bw_bits=8),
                dp_grad_bits=4,
                dp_wire="ring-sharded" if sh else ""),
            optimizer=opt, dp_workers=2)
        _, losses = sim.train(cfg, tcfg, Dataset(dc), num_steps=4,
                              batch_size=4, key=jax.random.PRNGKey(0))
        out[sh] = losses
    assert out[True][:2] == out[False][:2], out
    np.testing.assert_allclose(out[True], out[False], rtol=2e-3)


def test_bucket_adamw_bit_identical_to_leaf_adamw():
    """`adamw.apply_bucket_updates` (the segment-owner update of the
    ring-sharded wire) is ELEMENTWISE bit-identical to the per-leaf
    `apply_updates` over chained steps — the anchor that lets the
    sharded pipeline reproduce the replicated optimizer bit-for-bit on
    the same gradient stream."""
    from repro.optim import adamw
    from repro.optim.adamw import AdamWConfig
    cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    tree = _tree(seed=50)
    tree = jax.tree.map(lambda a: a.astype(jnp.float32), tree)
    grads = jax.tree.map(lambda a: a * 0.01, tree)
    lay = GC.bucket_layout(tree, GROUP)
    w = 2
    seg = -(-lay.rows // w)
    pad = seg * w - lay.rows

    @jax.jit
    def leaf_steps(params, grads):
        st = adamw.init_opt_state(params)
        for _ in range(3):
            params, st = adamw.apply_updates(cfg, params, grads, st)
        return params

    @jax.jit
    def bucket_steps(params, grads):
        st = adamw.init_bucket_opt_state(w, seg, lay.group_d)
        gb = GC.flatten_bucket(grads, lay)
        if pad:
            gb = jnp.pad(gb, ((0, pad), (0, 0)))
        gb = gb.reshape(w, seg, lay.group_d)
        for _ in range(3):
            pb = GC.flatten_bucket(params, lay)
            if pad:
                pb = jnp.pad(pb, ((0, pad), (0, 0)))
            new_pb, st = adamw.apply_bucket_updates(
                cfg, pb.reshape(w, seg, lay.group_d), gb, st)
            params = GC.unflatten_bucket(
                new_pb.reshape(w * seg, lay.group_d)[:lay.rows], lay,
                params)
        return params

    a, b = leaf_steps(tree, grads), bucket_steps(tree, grads)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=k)


@pytest.mark.parametrize("n_ranks,daxes", [
    (2, ("data",)), (3, ("data",)), (5, ("data",)), (8, ("data",)),
    (4, ("pod", "data")), (6, ("pod", "data")),
])
def test_dp_error_layout_matches_train_step(n_ranks, daxes):
    """Layout-drift gate for the sharded DP carries: on every mesh
    shape the workers exercise, `init_dp_error` (what launchers
    allocate) and `make_state_structs` (what `make_train_step` traces
    against) must agree on the dp_error shape, and `init_sharded_opt`
    must produce exactly one `ring_segment_rows` segment per DP rank —
    so the sharded carry cannot silently desync from the wire's
    segment schedule."""
    from types import SimpleNamespace
    from repro.configs.base import get_config
    from repro.core import collectives as C
    from repro.models import model as Mo
    from repro.training import pipeline as PL

    from repro.comm import CommConfig

    cfg = get_config("gpt2-xl-paper", smoke=True).with_(num_layers=2)
    pcfg = PL.PipelineConfig(comm=CommConfig.from_legacy(
        None, dp_grad_bits=4, dp_wire="ring-sharded"))
    params_shape = jax.eval_shape(
        lambda: PL.to_pipeline_params(
            cfg, Mo.init_params(cfg, jax.random.PRNGKey(0)), 2))
    lay = GC.bucket_layout(params_shape, pcfg.comm.dp_group_d)

    err = jax.eval_shape(
        lambda: PL.init_dp_error(pcfg, params_shape, n_ranks))
    assert err.shape == (n_ranks, lay.rows, lay.group_d), err

    # make_state_structs must derive the identical struct (it calls
    # eval_shape of the same init functions — pinned here so a future
    # re-derivation cannot drift)
    shape = {"model": 2}
    if daxes == ("data",):
        shape["data"] = n_ranks
        names = ("data", "model")
    else:
        shape["pod"], shape["data"] = 2, n_ranks // 2
        names = ("pod", "data", "model")
    mesh = SimpleNamespace(axis_names=names, shape=shape)
    meta = {"params_shape": params_shape, "m": 2, "trunk_seq": 16,
            "buffer_samples": 2}
    state, _, _ = PL.make_state_structs(
        cfg, pcfg, meta, mesh, global_batch=2 * n_ranks, seq_len=16)
    assert state["dp_error"].shape == err.shape
    assert state["dp_error"].dtype == jnp.float32

    seg = C.ring_segment_rows(lay.rows, n_ranks)
    opt = jax.eval_shape(
        lambda: PL.init_sharded_opt(pcfg, params_shape, n_ranks))
    assert opt["mu"].shape == (n_ranks, seg, lay.group_d), opt["mu"]
    assert state["opt"]["mu"].shape == opt["mu"].shape
    # ceil-division minimality: covers the bucket, one fewer row per
    # segment would not
    assert seg * n_ranks >= lay.rows
    assert (seg - 1) * n_ranks < lay.rows


@pytest.mark.parametrize("bits", [4, 8])
def test_compress_allreduce_tracks_true_mean(bits):
    """Deterministic sanity: the compressed mean is within one
    quantization cell (of the shared scale) of the exact mean."""
    trees = [_tree(seed=30 + i, scale=0.5 + 0.2 * i) for i in range(4)]
    lay = GC.bucket_layout(trees[0], GROUP)
    err0 = jnp.stack([GC.init_error_state(trees[0], GROUP)] * 4)
    mean, _ = GC.compress_allreduce(trees, err0, bits, KEY,
                                    stochastic=False, layout=lay)
    v = jnp.stack([GC.flatten_bucket(t, lay) for t in trees])
    true = jnp.mean(v, axis=0)
    got = GC.flatten_bucket(mean, lay)
    cell = 2.0 * np.asarray(jnp.max(jnp.abs(v), axis=(0, -1)),
                            np.float32) / ((1 << bits) - 1)
    assert np.max(np.abs(np.asarray(got - true)), axis=None) \
        <= np.max(cell) * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# opt-in on-core PRNG (REPRO_ONCORE_PRNG=1): statistical contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4])
def test_oncore_prng_unbiased_10k_trials(bits, monkeypatch):
    """The on-core PRNG encode path (pltpu.prng_random_bits instead of
    an HBM noise tensor) relaxes ref↔pallas parity to a STATISTICAL
    contract; this 10k-trial unbiasedness gate (the same harness as the
    noise-tensor test above) is what lets it ship.  TPU-only: interpret
    mode has no CPU lowering for prng_seed, so this skips on CPU."""
    from repro.kernels import ops as K

    if not K.oncore_prng_supported():
        pytest.skip("on-core PRNG has no lowering on this backend "
                    "(CPU interpret mode)")
    monkeypatch.setenv("REPRO_ONCORE_PRNG", "1")
    n_trials = 10_000
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 64))
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True),
                        1e-12)
    # one fused call over the tiled batch: every row draws iid on-core
    # noise (blocks seed with the key words + grid position)
    xt = jnp.tile(x, (n_trials, 1))
    st = jnp.tile(scale, (n_trials, 1))
    codes = B.encode_codes_with_scale(xt, st, bits=bits, stochastic=True,
                                      key=jax.random.PRNGKey(6),
                                      backend="pallas")
    q = B.decode_sum_mean(codes, st, bits=bits, n=1, backend="reference")
    est = np.asarray(q).reshape(n_trials, 4, 64).mean(axis=0)
    cell = 2.0 * np.asarray(scale) / ((1 << bits) - 1)
    bound = 5.0 * cell / (2.0 * np.sqrt(n_trials))
    err = np.abs(est - np.asarray(x))
    assert np.max(err / bound) < 1.0, float(np.max(err / bound))
    # and the stream is deterministic given the key
    codes2 = B.encode_codes_with_scale(xt, st, bits=bits, stochastic=True,
                                       key=jax.random.PRNGKey(6),
                                       backend="pallas")
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes2))


def test_oncore_prng_gate_refuses_without_support(monkeypatch):
    """REPRO_ONCORE_PRNG=1 on a backend that cannot lower prng_seed must
    fail loudly at the boundary layer, not crash inside lowering."""
    from repro.kernels import ops as K

    if K.oncore_prng_supported():
        pytest.skip("on-core PRNG supported here; gate cannot trip")
    monkeypatch.setenv("REPRO_ONCORE_PRNG", "1")
    v = jax.random.normal(jax.random.PRNGKey(11), (8, 64))
    s = jnp.max(jnp.abs(v), axis=-1, keepdims=True)
    with pytest.raises(ValueError, match="REPRO_ONCORE_PRNG"):
        B.encode_codes_with_scale(v, s, bits=4, stochastic=True, key=KEY,
                                  backend="pallas")


# ---------------------------------------------------------------------------
# chunked encoder determinism (the double-buffered ring's sender)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("stoch", [False, True])
@pytest.mark.parametrize("n,chunks", [(2, 2), (3, 2), (4, 3), (4, 4)])
def test_chunk_encoder_bit_identical_to_monolithic(bits, stoch, n,
                                                   chunks, monkeypatch):
    """`collectives.make_chunk_encoder` — the double-buffered ring's
    per-chunk sender — reassembles to the BIT-IDENTICAL packed payload,
    codes, and error carry `grad_compress.ef_encode` produces for the
    same key, for every chunk count including ragged ones.  With the
    on-core PRNG opt-in OFF, the chunked path's once-drawn row-sliced
    noise is exactly the boundary `_noise` draw, so stochastic rounding
    is chunking-invariant too (the on-core stream is grid-position-
    dependent, which is why the encoder pins noise explicitly)."""
    from repro.core import collectives as C

    monkeypatch.delenv("REPRO_ONCORE_PRNG", raising=False)
    rows, d = 79, 128
    v = jax.random.normal(jax.random.PRNGKey(21), (rows, d)) * 0.7
    v = v.at[3].set(0.0)
    s = jnp.max(jnp.abs(v), axis=-1, keepdims=True)
    packed_m, codes_m, err_m = GC.ef_encode(v, s, bits, KEY,
                                            stochastic=stoch,
                                            backend="reference",
                                            pack=True)
    seg = C.ring_segment_rows(rows, n)
    bounds = C.ring_chunk_bounds(seg, chunks)
    enc = C.make_chunk_encoder(v, s, bits, KEY, n, bounds,
                               stochastic=stoch, backend="reference")
    packed_c = jnp.concatenate([enc(ci)[0] for ci in
                                range(len(bounds))], axis=1)
    codes_c = jnp.concatenate([enc(ci)[1] for ci in
                               range(len(bounds))], axis=1)
    live_p = packed_c.reshape(n * seg, -1)[:rows]
    live_c = codes_c.reshape(n * seg, d)[:rows]
    np.testing.assert_array_equal(np.asarray(live_p),
                                  np.asarray(packed_m))
    np.testing.assert_array_equal(np.asarray(live_c),
                                  np.asarray(codes_m))
    # pad rows (ragged last segment) are zeroed in code space
    pad_c = np.asarray(codes_c.reshape(n * seg, d)[rows:])
    assert pad_c.size == 0 or not pad_c.any()
    # the error carry recomputed from the reassembled codes matches
    q = B.decode_sum_mean(live_c, s, bits=bits, n=1,
                          backend="reference")
    np.testing.assert_array_equal(np.asarray(v - q), np.asarray(err_m))


# ---------------------------------------------------------------------------
# the gradient path is fused end-to-end (no unfused quantize calls)
# ---------------------------------------------------------------------------

def test_gradient_path_has_no_unfused_quantize_calls():
    """Every quantize/pack/unpack on the gradient path must route
    through core.boundary's fused backend-selectable ops — never the
    per-leaf `Q.qdq` loop this wire replaced, nor any other unfused
    `Q.*` chain (same gate PR 1 established for the activation path).
    The assertion lives in the `no-unfused-quantize` lint rule
    (repro.analysis), which covers grad_compress, collectives,
    simulated and pipeline alias-proof; this is its one-line test
    invocation."""
    from repro.analysis import run_rule

    assert run_rule("no-unfused-quantize") == []


# ---------------------------------------------------------------------------
# Fig. 5a convergence regression (slow tier -> nightly CI)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fig5a_aqsgd_grad4_tracks_fp32():
    """End-to-end communication compression (Fig. 5a): AQ-SGD fw3/bw6
    plus 4-bit error-feedback gradient compression fine-tunes to within
    tolerance of FP32, and beats DirectQ under the same gradient wire —
    so a quality regression in the compressed wire fails CI nightly
    instead of silently shipping."""
    from benchmarks.common import finetune, tail_loss

    steps = 50
    l_fp, _ = finetune("fp32", steps=steps)
    l_aq, _ = finetune("aqsgd", 3, 6, steps=steps, dp_grad_bits=4,
                       dp_workers=2)
    l_dq, _ = finetune("directq", 3, 6, steps=steps, dp_grad_bits=4,
                       dp_workers=2)
    fp, aq, dq = tail_loss(l_fp), tail_loss(l_aq), tail_loss(l_dq)
    assert np.isfinite([fp, aq, dq]).all(), (fp, aq, dq)
    assert aq < dq, f"AQ-SGD {aq:.4f} must beat DirectQ {dq:.4f}"
    # "tracks FP32": the AQ-SGD gap stays well under half the DirectQ
    # gap AND under an absolute drift cap (reference run: fp 3.01,
    # aq 3.20, dq 3.71 — gaps 0.20 vs 0.70)
    assert abs(aq - fp) < 0.5 * abs(dq - fp) + 1e-6, (fp, aq, dq)
    assert abs(aq - fp) < 0.35, \
        f"AQ-SGD+grad4 tail {aq:.4f} drifted from FP32 {fp:.4f}"
