"""Unit tests for the uniform quantizer and wire packing.

Hypothesis property tests live in tests/test_properties.py (guarded by
pytest.importorskip so collection succeeds without hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantization as q


KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("stochastic", [False, True])
def test_roundtrip_error_bound(bits, stochastic):
    x = jax.random.normal(KEY, (16, 256), dtype=jnp.float32)
    codes, scale = q.quantize(x, bits, stochastic=stochastic, key=KEY)
    xh = q.dequantize(codes, scale, bits)
    # uniform grid over [-scale, scale]: max error = half a cell for
    # deterministic rounding, one cell for stochastic.
    cell = 2.0 * np.asarray(scale) / ((1 << bits) - 1)
    err = np.abs(np.asarray(xh - x))
    factor = 1.0 if stochastic else 0.5
    assert np.all(err <= factor * cell + 1e-6)


def test_stochastic_rounding_unbiased():
    # E[Q(x)] = x: average many independent stochastic quantizations.
    x = jax.random.uniform(KEY, (64,), minval=-1, maxval=1)
    keys = jax.random.split(jax.random.PRNGKey(1), 4096)
    qd = jax.vmap(lambda k: q.qdq(x, 2, key=k))(keys)
    mean = np.asarray(qd.mean(axis=0))
    assert np.allclose(mean, np.asarray(x), atol=0.02)


def test_relative_error_contraction():
    # the theory's requirement E||x - Q(x)|| <= c_Q ||x|| with c_Q < sqrt(1/2)
    # holds comfortably at >=4 bits for per-row scales on gaussian data.
    x = jax.random.normal(KEY, (32, 512))
    keys = jax.random.split(jax.random.PRNGKey(2), 64)
    errs = []
    for k in keys[:8]:
        xh = q.qdq(x, 4, key=k)
        errs.append(np.linalg.norm(np.asarray(xh - x)) /
                    np.linalg.norm(np.asarray(x)))
    assert np.mean(errs) < np.sqrt(0.5)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("n", [1, 3, 8, 127, 256])
def test_pack_unpack_roundtrip(bits, n):
    maxc = (1 << bits) - 1
    codes = jax.random.randint(KEY, (4, n), 0, maxc + 1, dtype=jnp.int32)
    codes = codes.astype(jnp.uint8)
    packed = q.pack_codes(codes, bits)
    assert packed.shape == (4, q.packed_width(n, bits))
    out = q.unpack_codes(packed, bits, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


def test_wire_bytes():
    # 2-bit packing of a (8, 100) tensor: 25 bytes/row + 4-byte scale.
    assert q.wire_bytes((8, 100), 2) == 8 * 25 + 8 * 4
    assert q.wire_bytes((8, 100), 8) == 8 * 100 + 8 * 4


def test_wire_roundtrip_equals_qdq():
    """Wire form (quantize→pack→unpack→dequantize) == fake-quant qdq."""
    for bits in (2, 4, 8):
        for n in (1, 3, 100, 128):
            key = jax.random.PRNGKey(bits * 1000 + n)
            x = jax.random.normal(key, (4, n), dtype=jnp.float32) * 3.0
            codes, scale = q.quantize(x, bits, stochastic=False)
            wire = q.pack_codes(codes, bits)
            xh_wire = q.dequantize(q.unpack_codes(wire, bits, n), scale,
                                   bits)
            xh_sim = q.qdq(x, bits, stochastic=False)
            np.testing.assert_allclose(np.asarray(xh_wire),
                                       np.asarray(xh_sim), rtol=0, atol=0)


def test_noise_route_matches_key_route():
    """quantize(noise=uniform(key)) == quantize(key=key): the identity
    that lets the Pallas backend share one noise draw with the
    reference chain."""
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(jax.random.PRNGKey(10), (16, 128)) * 2.0
    for bits in (2, 4, 8):
        c1, s1 = q.quantize(x, bits, stochastic=True, key=key)
        u = jax.random.uniform(key, x.shape, jnp.float32)
        c2, s2 = q.quantize(x, bits, stochastic=True, noise=u)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_zero_input_safe():
    x = jnp.zeros((4, 16))
    out = q.qdq(x, 2, stochastic=False)
    assert not np.any(np.isnan(np.asarray(out)))
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-9)
