"""Pallas flash-attention kernel: shape/dtype/GQA/window/softcap sweep
against the pure-jnp oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import flash_attention_ref


def _setup(b, h, hk, s, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, s, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hk, s, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hk, s, hd), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("b,h,hk,s,hd,bq,bk", [
    (1, 2, 2, 64, 32, 16, 16),
    (2, 4, 2, 128, 64, 32, 64),     # GQA groups=2
    (1, 8, 1, 64, 128, 64, 16),     # MQA
    (1, 2, 2, 96, 32, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_shapes_dtypes(b, h, hk, s, hd, bq, bk, dtype):
    q, k, v = _setup(b, h, hk, s, hd, dtype)
    o = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    groups = h // hk
    ref = flash_attention_ref(q, jnp.repeat(k, groups, 1),
                              jnp.repeat(v, groups, 1))
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window,cap,causal", [
    (9, 0.0, True), (10 ** 9, 30.0, True), (17, 4.0, True),
    (10 ** 9, 0.0, False),
])
def test_flash_kernel_masks(window, cap, causal):
    q, k, v = _setup(1, 2, 2, 64, 32, jnp.float32, seed=5)
    o = ops.flash_attention(q, k, v, window=window, softcap=cap,
                            causal=causal, block_q=16, block_k=16)
    ref = flash_attention_ref(q, k, v, window=window, softcap=cap,
                              causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_kernel_matches_model_layer_path():
    """Kernel == the JAX-level flash used by the model trunk."""
    from repro.models import layers as L
    b, s, h, hd = 1, 64, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    jax_flash = L.flash_attention(q, k, v, q_pos=pos, k_pos=pos,
                                  window=11, block_k=16)
    kernel = ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), window=11, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(jax_flash),
                               np.asarray(kernel.transpose(0, 2, 1, 3)),
                               rtol=2e-5, atol=2e-5)
