"""Gates on the static-analysis subsystem itself (`repro.analysis`).

Three layers of proof:

1. Every lint rule fires on a seeded-violation snippet and stays
   silent on the clean twin — via `lint_text`, the in-memory fixture
   entry point, so no bad code ever touches the tree.  The alias
   fixtures pin the exact blind spot the old regex scans had
   (``from os import environ as e``).
2. The engine mechanics: suppression comments (same line, line above,
   file-wide, ``all``), the rule catalog contract (>= 8 rules, unique
   ids, complete metadata), parse-error surfacing, and — the gate CI
   rides on — the repo itself lints clean.
3. The HLO collective auditor: the CLI's full audit pins the EXACT
   inventory (kind, dtype, bytes, count, group span) of every
   registered DP wire at b in {2, 4, 8} on the 4-device ring, and a
   deliberately-broken wire (an f32 psum smuggled past its manifest)
   fails with a diff that names the unexpected op (slow tier —
   subprocess compiles, like every host-mesh regression).
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import (get_rule, iter_rules, lint_text, run_lint,
                            run_rule)
from test_distributed import run_worker

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# 1. every rule: one snippet that MUST flag, one that MUST pass
# ---------------------------------------------------------------------------

CASES = [
    ("no-unfused-quantize",
     "from repro.core import quantization as QQ\n"
     "def send(x):\n"
     "    return QQ.quantize(x, bits=2)\n",
     "src/repro/training/newmod.py",
     "from repro.core import boundary as B\n"
     "def send(x, key):\n"
     "    return B.roundtrip(x, bits=2, stochastic=False, key=key)\n",
     "src/repro/training/newmod.py"),
    ("no-stray-env-read",
     "from os import environ as e\n"
     "FLAG = e['REPRO_DEBUG']\n",
     "src/repro/newmod.py",
     "import os\n"
     "HOME = os.environ['HOME']\n",          # non-REPRO_* read is fine
     "src/repro/newmod.py"),
    ("no-legacy-comm-kwargs",
     "cfg = PipelineConfig(dp_wire='ring', dp_grad_bits=4)\n",
     "examples/newmod.py",
     "cfg = PipelineConfig(comm=CommConfig(dp=PlaneConfig(bits=4)))\n",
     "examples/newmod.py"),
    ("registry-completeness",
     "W.register_wire('x', plane='dp-grad', collective=fn)\n",
     "src/repro/comm/newwire.py",
     "W.register_wire('x', plane='dp-grad', collective=fn,\n"
     "                wire_bytes=bb, sim_allreduce=sim,\n"
     "                expected_collectives=manifest)\n",
     "src/repro/comm/newwire.py"),
    ("no-host-callables-in-jit",
     "import time\n"
     "import jax\n"
     "@jax.jit\n"
     "def f(x):\n"
     "    return x + time.time()\n",
     "src/repro/core/newmod.py",
     "import time\n"
     "import jax\n"
     "@jax.jit\n"
     "def f(x):\n"
     "    return x + 1\n"
     "def bench(x):\n"
     "    t0 = time.time()\n"                  # outside jit: supported
     "    return f(x), time.time() - t0\n",
     "src/repro/core/newmod.py"),
    ("no-silent-dtype-upcast",
     "import numpy as np\n"
     "def f(x):\n"
     "    return np.asarray(x, dtype=np.float64)\n",
     "src/repro/core/newmod.py",
     "import numpy as np\n"
     "def f(x):\n"
     "    return np.asarray(x, dtype=np.float32)\n",
     "src/repro/core/newmod.py"),
    ("no-raw-shard-map-import",
     "from jax.experimental.shard_map import shard_map\n",
     "src/repro/training/newmod.py",
     "from repro.launch.mesh import shard_map\n",
     "src/repro/training/newmod.py"),
    ("no-getsource-scan",
     "import inspect\n"
     "src = inspect.getsource(object)\n",
     "tests/test_newmod.py",
     "import inspect\n"
     "sig = inspect.signature(object)\n",
     "tests/test_newmod.py"),
    ("no-direct-collective",
     "import jax\n"
     "def f(x):\n"
     "    return jax.lax.psum(x, 'd')\n",
     "src/repro/models/newmod.py",
     "from repro.core import collectives as C\n"
     "def f(x, err, key):\n"
     "    return C.compressed_ring_allreduce(x, err, 'd', 4, key)\n",
     "src/repro/models/newmod.py"),
]


@pytest.mark.parametrize("rule_id,bad,bad_path,clean,clean_path",
                         CASES, ids=[c[0] for c in CASES])
def test_rule_fires_and_stays_silent(rule_id, bad, bad_path, clean,
                                     clean_path):
    rules = [get_rule(rule_id)]
    hits = lint_text(bad, bad_path, rules)
    assert hits, f"{rule_id} missed its seeded violation"
    assert all(f.rule == rule_id for f in hits)
    assert all(f.fix_hint for f in hits)
    assert lint_text(clean, clean_path, rules) == [], \
        f"{rule_id} false-positive on the clean snippet"


@pytest.mark.parametrize("snippet,rule_id,path", [
    # the exact blind spot of check_docs.py's old regex scan
    ("from os import environ as e\nx = e['REPRO_X']\n",
     "no-stray-env-read", "src/repro/newmod.py"),
    ("from os import getenv as g\nx = g('REPRO_X')\n",
     "no-stray-env-read", "src/repro/newmod.py"),
    ("import os as o\nx = o.environ.get('REPRO_X')\n",
     "no-stray-env-read", "src/repro/newmod.py"),
    # aliased from-import of a banned quantization op
    ("from repro.core.quantization import qdq as q\ny = q(x, 2)\n",
     "no-unfused-quantize", "src/repro/training/newmod.py"),
    # aliased getsource
    ("import inspect as insp\ns = insp.getsource(object)\n",
     "no-getsource-scan", "tests/test_newmod.py"),
], ids=["env-alias", "getenv-alias", "os-alias", "quant-from-import",
        "inspect-alias"])
def test_import_aliases_cannot_dodge(snippet, rule_id, path):
    hits = lint_text(snippet, path, [get_rule(rule_id)])
    assert hits and hits[0].rule == rule_id


# ---------------------------------------------------------------------------
# 2. engine mechanics
# ---------------------------------------------------------------------------

_BAD = ("import inspect\n"
        "src = inspect.getsource(object)\n")
_RULE = "no-getsource-scan"


def _hits(text):
    return lint_text(text, "tests/test_newmod.py", [get_rule(_RULE)])


def test_suppression_same_line():
    text = _BAD.replace(
        "src = inspect.getsource(object)",
        "src = inspect.getsource(object)"
        "  # repro-lint: disable=no-getsource-scan")
    assert _hits(_BAD) and _hits(text) == []


def test_suppression_comment_line_above():
    text = _BAD.replace(
        "src = inspect.getsource(object)\n",
        "# repro-lint: disable=no-getsource-scan\n"
        "src = inspect.getsource(object)\n")
    assert _hits(text) == []


def test_suppression_file_wide_and_all():
    assert _hits("# repro-lint: disable-file=no-getsource-scan\n"
                 + _BAD) == []
    assert _hits(_BAD.replace(
        "src = inspect.getsource(object)",
        "src = inspect.getsource(object)  # repro-lint: disable=all")
    ) == []


def test_suppression_for_other_rule_does_not_apply():
    text = _BAD.replace(
        "src = inspect.getsource(object)",
        "src = inspect.getsource(object)"
        "  # repro-lint: disable=no-stray-env-read")
    assert len(_hits(text)) == 1


def test_rule_catalog_contract():
    """>= 8 rules (the ISSUE floor), unique ids, complete metadata."""
    rules = iter_rules()
    assert len(rules) >= 8
    assert len({r.id for r in rules}) == len(rules)
    for r in rules:
        assert r.summary and r.rationale and r.fix_hint
        assert r.severity in ("error", "warning")


def test_unknown_rule_is_loud():
    with pytest.raises(ValueError, match="unknown lint rule"):
        get_rule("no-such-rule")


def test_parse_error_surfaces_as_finding(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "broken.py").write_text("def f(:\n")
    findings = run_lint(tmp_path)
    assert [f.rule for f in findings] == ["parse-error"]
    assert findings[0].path == "src/broken.py"


def test_repo_lints_clean():
    """The gate CI rides on: the tree itself has zero findings (the
    getsource scans this subsystem replaced are gone, the deliberate
    raise-path fixtures carry suppressions)."""
    assert run_lint() == []


def test_run_rule_is_the_one_line_gate():
    """`run_rule` is the entry point the old getsource tests were
    replaced with — scoped to one rule, empty on a clean tree."""
    assert run_rule("no-unfused-quantize") == []


# ---------------------------------------------------------------------------
# 3. CLI and the HLO collective auditor
# ---------------------------------------------------------------------------

def _cli(*args, timeout=120):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=ROOT)


def test_cli_lint_layer_exits_clean():
    r = _cli("--skip-collectives")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 lint finding(s)" in r.stdout


def test_cli_lists_rule_catalog():
    r = _cli("--list-rules")
    assert r.returncode == 0
    lines = [ln for ln in r.stdout.splitlines() if "[error]" in ln
             or "[warning]" in ln]
    assert len(lines) >= 8


@pytest.mark.slow
def test_cli_full_audit_pins_every_wire_inventory(tmp_path):
    """`python -m repro.analysis --json` (the CI invocation) must exit
    0 with every registered DP wire's collective inventory matching
    its manifest at b in {2, 4, 8} — and the b=2 inventories are
    pinned here op-by-op, so neither the manifests nor the lowering
    can drift without this test naming the change."""
    out = tmp_path / "report.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--json", str(out)],
        capture_output=True, text=True, timeout=900, env=env, cwd=ROOT)
    assert r.returncode == 0, r.stdout + r.stderr[-3000:]
    data = json.loads(out.read_text())
    assert data["ok"] and not data["lint"]["findings"]
    assert len(data["lint"]["rules"]) >= 8

    audits = {(a["wire"], a["bits"]): a for a in data["collectives"]}
    assert {w for w, _ in audits} == {"ring", "psum", "ring-sharded",
                                      "fp16"}
    assert len(audits) == 12 and all(a["ok"] for a in audits.values())
    for a in audits.values():           # every op spans the full ring
        assert all(c["groups"] == 4 for c in a["inventory"])

    def inv(wire, bits):
        return sorted((c["kind"], c["dtype"], c["bytes"], c["count"])
                      for c in audits[(wire, bits)]["inventory"])

    # (128, 256) bucket, n=4, b=2 — scale pmax + 3 code hops + 3
    # packed-sum hops for the ring; i32-lane psum; ZeRO ring; fp16
    assert inv("ring", 2) == [("all-reduce", "f32", 512, 1),
                              ("collective-permute", "u8", 2048, 3),
                              ("collective-permute", "u8", 4096, 3)]
    assert inv("psum", 2) == [("all-reduce", "f32", 512, 1),
                              ("all-reduce", "s32", 131072, 1)]
    assert inv("ring-sharded", 2) == [
        ("all-reduce", "f32", 512, 1),
        ("collective-permute", "u8", 2048, 3)]
    assert inv("fp16", 2) == [("all-reduce", "f16", 65536, 1)]


@pytest.mark.slow
def test_auditor_fires_on_smuggled_collective():
    """The seeded auditor violation: a wire whose collective smuggles
    an f32 psum its manifest never declared must FAIL with a diff that
    names the unexpected all-reduce (and the PR-4 compressed-path
    callout); a wire with no manifest at all must fail too."""
    stdout = run_worker("analysis_worker.py", "run", timeout=900)
    line = [ln for ln in stdout.splitlines()
            if ln.startswith("ANALYSIS ")][0]
    out = json.loads(line[len("ANALYSIS "):])

    broken = out["broken"]
    assert not broken["ok"]
    assert broken["jaxpr"].get("psum", 0) >= 1      # traced request
    msgs = "\n".join(broken["problems"])
    assert "unexpected collective" in msgs
    assert "all-reduce f32 131072" in msgs          # 128*256*4 B
    assert "PR-4" in msgs                           # compressed-path
    # the legitimate fp16 payload still matches — ONLY the smuggled op
    # is flagged, so the diff points at the bug, not at noise
    assert not any("missing collective" in p for p in broken["problems"])

    naked = out["naked"]
    assert not naked["ok"]
    assert any("no expected_collectives manifest" in p
               for p in naked["problems"])
