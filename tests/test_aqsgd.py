"""Tests for the AQ-SGD core: boundary semantics, buffer codec, gradient
quantization, and the paper's headline qualitative claim (AQ-SGD tracks
FP32 where DirectQ degrades, at aggressive bit widths)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.configs.base import get_config
from repro.core import aqsgd
from repro.core import quantization as Q
from repro.core.aqsgd import CompressionConfig
from repro.data.pipeline import Dataset, DatasetConfig
from repro.optim.adamw import AdamWConfig
from repro.training import simulated as sim

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# boundary op semantics
# ---------------------------------------------------------------------------

def test_first_visit_sends_full_precision():
    cc = CompressionConfig(mode="aqsgd", fw_bits=2)
    h = jax.random.normal(KEY, (4, 8, 16))
    m = jnp.zeros_like(h)
    seen = jnp.zeros((4,), bool)
    h_out, m_new = aqsgd.apply_boundary(cc, h, KEY, m, seen)
    np.testing.assert_allclose(np.asarray(h_out), np.asarray(h), atol=1e-6)
    np.testing.assert_allclose(np.asarray(m_new), np.asarray(h), atol=1e-6)


def test_revisit_sends_quantized_delta():
    cc = CompressionConfig(mode="aqsgd", fw_bits=4, stochastic=False)
    h = jax.random.normal(KEY, (4, 8, 16))
    m = h + 0.01 * jax.random.normal(jax.random.PRNGKey(1), h.shape)
    seen = jnp.ones((4,), bool)
    h_out, m_new = aqsgd.apply_boundary(cc, h, KEY, m, seen)
    expect = m + Q.qdq(h - m, 4, stochastic=False)
    np.testing.assert_allclose(np.asarray(h_out), np.asarray(expect),
                               atol=1e-6)
    # self-reinforcing property: message error shrinks vs direct quant
    err_aq = float(jnp.linalg.norm(h - m_new))
    err_dq = float(jnp.linalg.norm(h - Q.qdq(h, 4, stochastic=False)))
    assert err_aq < err_dq


def test_backward_gradient_is_quantized():
    cc = CompressionConfig(mode="directq", fw_bits=8, bw_bits=2,
                           stochastic=False)

    def f(h):
        out, _ = aqsgd.apply_boundary(cc, h, KEY)
        return jnp.sum(out ** 3)

    h = jax.random.normal(KEY, (2, 4, 8))
    g = jax.grad(f)(h)
    out, _ = aqsgd.apply_boundary(cc, h, KEY)
    true_g = 3.0 * out ** 2                     # upstream gradient at m
    # bwd applies qdq(true_g) with bw_bits and the bwd sub-key
    _, kb = jax.random.split(KEY)
    expect = Q.qdq(true_g, 2, stochastic=False)
    np.testing.assert_allclose(np.asarray(g), np.asarray(expect), atol=1e-5)
    # 2-bit quantization must actually have changed something
    assert float(jnp.max(jnp.abs(g - true_g))) > 1e-3


def test_non_byte_aligned_bits_still_supported():
    """The paper's fw3/bw6 ablation widths (not densely packable) must
    keep working through the boundary op — they route to the reference
    chain with raw u8 codes and match the fake-quant semantics."""
    cc = CompressionConfig(mode="aqsgd", fw_bits=3, bw_bits=6,
                           stochastic=False)
    h = jax.random.normal(KEY, (4, 8, 16))
    m = h + 0.01 * jax.random.normal(jax.random.PRNGKey(1), h.shape)
    seen = jnp.ones((4,), bool)
    h_out, m_new = aqsgd.apply_boundary(cc, h, KEY, m, seen)
    expect = m + Q.qdq(h - m, 3, stochastic=False)
    np.testing.assert_allclose(np.asarray(h_out), np.asarray(expect),
                               atol=1e-6)
    g = jax.grad(lambda x: jnp.sum(
        aqsgd.apply_boundary(cc, x, KEY, m, seen)[0] ** 2))(h)
    assert np.isfinite(np.asarray(g)).all()


def test_fp32_mode_is_identity_with_gradient():
    cc = CompressionConfig(mode="fp32")
    h = jax.random.normal(KEY, (2, 4, 8))
    out, m_new = aqsgd.apply_boundary(cc, h, KEY)
    assert m_new is None
    np.testing.assert_array_equal(np.asarray(out), np.asarray(h))
    g = jax.grad(lambda x: jnp.sum(aqsgd.apply_boundary(cc, x, KEY)[0] ** 2)
                 )(h)
    np.testing.assert_allclose(np.asarray(g), np.asarray(2 * h), atol=1e-6)


# ---------------------------------------------------------------------------
# buffer codec (fp and z-bit storage, §H.5)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("buffer_bits", [0, 8, 4])
def test_buffer_roundtrip(buffer_bits):
    cc = CompressionConfig(mode="aqsgd", buffer_bits=buffer_bits)
    bufs = aqsgd.init_buffers(cc, 2, 10, 8, 16)
    ids = jnp.array([3, 7], jnp.int32)
    m = jax.random.normal(KEY, (2, 8, 16))
    bufs = aqsgd.write_buffer(cc, bufs, 1, ids, m)
    got = aqsgd.read_buffer(cc, bufs, 1, ids, 16)
    tol = 1e-6 if buffer_bits == 0 else \
        float(jnp.max(jnp.abs(m))) * 2.0 / ((1 << buffer_bits) - 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(m), atol=tol)
    assert bool(bufs["seen"][1, 3]) and bool(bufs["seen"][1, 7])
    assert not bool(bufs["seen"][0, 3])


def test_buffer_nbytes_matches_paper_scale():
    """GPT2-XL example from §3.3: buffers for the full corpus are ~1 TB
    in fp32 when the boundary tensor is seq 1024 × d 1600 over 7
    boundaries and a WikiText2-scale corpus (~2M tokens / 1024)."""
    cc = CompressionConfig(mode="aqsgd")
    n_samples = 2_000_000 // 1024
    b = aqsgd.buffer_nbytes(cc, 7, n_samples, 1024, 1600)
    assert 50e9 < b < 200e9   # per-boundary-pair copy; x2 sides + opt state
    # and z-bit storage cuts it ~8x (4-bit + scales)
    cc4 = cc.with_(buffer_bits=4)
    assert aqsgd.buffer_nbytes(cc4, 7, n_samples, 1024, 1600) < b / 6


# ---------------------------------------------------------------------------
# simulated trainer end-to-end semantics
# ---------------------------------------------------------------------------

def _mini_setup(mode, fw_bits=2, bw_bits=4, steps=30, stages=4, lr=2e-3,
                dp_grad_bits=0, dp_workers=1, buffer_bits=0,
                initial_params=None):
    mcfg = get_config("gpt2-xl-paper", smoke=True).with_(num_layers=4)
    dc = DatasetConfig(num_samples=32, seq_len=32, vocab_size=512, seed=3)
    ds = Dataset(dc)
    tcfg = sim.SimTrainConfig(
        num_stages=stages,
        comm=CommConfig.from_legacy(
            CompressionConfig(mode=mode, fw_bits=fw_bits,
                              bw_bits=bw_bits, buffer_bits=buffer_bits),
            dp_grad_bits=dp_grad_bits),
        optimizer=AdamWConfig(lr=lr, warmup_steps=5, total_steps=steps,
                              schedule="constant"),
        dp_workers=dp_workers)
    state, losses = sim.train(mcfg, tcfg, ds, num_steps=steps, batch_size=8,
                              key=jax.random.PRNGKey(0),
                              initial_params=initial_params)
    return state, losses


@pytest.mark.slow
def test_fp32_pipeline_matches_no_pipeline():
    """K-stage fp32 simulation must equal monolithic training exactly."""
    _, l4 = _mini_setup("fp32", steps=6, stages=4)
    _, l1 = _mini_setup("fp32", steps=6, stages=1)
    np.testing.assert_allclose(l4, l1, rtol=1e-5)


@pytest.mark.slow
def test_paper_claim_aqsgd_tracks_fp32_directq_degrades():
    """Fig. 1a / Fig. 3: *fine-tuning* (the paper's setting) at fw2 bw4 —
    AQ-SGD stays close to FP32 while DirectQ is clearly worse."""
    # phase 1: pre-train a base model in fp32 (the "foundation model")
    base_state, base_losses = _mini_setup("fp32", steps=80, lr=2e-3)
    base = base_state["params"]
    assert np.mean(base_losses[-5:]) < 2.5       # learned the structure
    # phase 2: fine-tune at low lr with each compression mode
    steps = 40
    _, l_fp = _mini_setup("fp32", steps=steps, lr=3e-4,
                          initial_params=base)
    _, l_aq = _mini_setup("aqsgd", steps=steps, lr=3e-4,
                          initial_params=base)
    _, l_dq = _mini_setup("directq", steps=steps, lr=3e-4,
                          initial_params=base)
    tail = slice(-8, None)
    fp, aq, dq = (float(np.mean(l[tail])) for l in (l_fp, l_aq, l_dq))
    assert aq < dq, (fp, aq, dq)
    assert abs(aq - fp) < 0.5 * abs(dq - fp) + 1e-6, (fp, aq, dq)


@pytest.mark.slow
def test_low_precision_buffer_still_converges():
    """§H.5: 4-bit previous-message storage remains usable."""
    _, l = _mini_setup("aqsgd", steps=25, buffer_bits=4)
    assert np.isfinite(l).all()
    assert np.mean(l[-5:]) < np.mean(l[:5])


@pytest.mark.slow
def test_dp_gradient_compression_combo():
    """Fig. 5: AQ-SGD + error-feedback DP gradient compression trains."""
    _, l = _mini_setup("aqsgd", steps=20, dp_grad_bits=4, dp_workers=2)
    assert np.isfinite(l).all()
    assert np.mean(l[-5:]) < np.mean(l[:5])
