"""Fig. 9 reproduction: robustness ablations.

(a,b) number of pipeline stages K in {1, 2, 4} (the bench model has 4
layers; K=8 needs the deeper --full variants) — DirectQ degrades as K
grows (compression error accumulates across boundaries), AQ-SGD holds;
(c,d) bits sweep fw in {2, 3, 4, 8};
(e,f) previous-message (buffer) precision z in {2, 4, 8, 0=fp32}."""
from __future__ import annotations

from benchmarks.common import finetune, tail_loss, write_csv


def main(steps: int = 50) -> list:
    rows = []

    for k in (1, 2, 4):
        for mode in ("aqsgd", "directq"):
            losses, _ = finetune(mode, 2, 4, steps=steps, stages=k)
            tl = tail_loss(losses)
            rows.append(("stages", k, mode, f"{tl:.4f}"))
            print(f"ablation,stages={k},{mode},{tl:.4f}")

    for fw in (2, 3, 4, 8):
        for mode in ("aqsgd", "directq"):
            losses, _ = finetune(mode, fw, min(2 * fw, 8), steps=steps)
            tl = tail_loss(losses)
            rows.append(("fw_bits", fw, mode, f"{tl:.4f}"))
            print(f"ablation,fw_bits={fw},{mode},{tl:.4f}")

    for z in (0, 8, 4, 2):
        losses, _ = finetune("aqsgd", 2, 4, steps=steps, buffer_bits=z)
        tl = tail_loss(losses)
        rows.append(("buffer_bits", z or "fp32", "aqsgd", f"{tl:.4f}"))
        print(f"ablation,buffer_bits={z or 'fp32'},aqsgd,{tl:.4f}")

    write_csv("ablations.csv", "ablation,value,method,final_loss", rows)

    # claims: aqsgd <= directq at every K and every bit width
    by = {}
    for a, v, m, l in rows:
        by[(a, v, m)] = float(l)
    ok_k = all(by[("stages", k, "aqsgd")] <= by[("stages", k, "directq")]
               + 1e-3 for k in (2, 4))
    ok_b = all(by[("fw_bits", f, "aqsgd")] <= by[("fw_bits", f, "directq")]
               + 1e-3 for f in (2, 3, 4, 8))
    print(f"ablation,claim_aqsgd_dominates_over_stages,,{ok_k}")
    print(f"ablation,claim_aqsgd_dominates_over_bits,,{ok_b}")
    return rows


if __name__ == "__main__":
    main()
