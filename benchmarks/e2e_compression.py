"""Fig. 5 reproduction: AQ-SGD combined with data-parallel gradient
compression ("end-to-end communication compression").

(a/b) convergence: AQ-SGD fw3 bw6 + 4-bit error-feedback model-gradient
compression must track FP32 where DirectQ+gradient compression degrades.
(c) throughput: with both activation and gradient wires compressed, the
modeled end-to-end speedup over no-compression grows beyond
activation-only compression (paper: up to 8.5x at 100 Mbps).

The gradient wire measured here is the real fused path: the simulated
trainer routes ``dp_grad_bits`` through the bucketed error-feedback
codec of `core.grad_compress` (shared-scale fused codes-only quantize,
int32 code accumulation, fused dequant-mean) — bit-identical to ALL
THREE shard_map wires (`core.collectives.ef_psum_mean_bucket`, the
bandwidth-optimal `ring_ef_reduce_mean_bucket`, and the ZeRO-sharded
`ring_ef_reduce_scatter_bucket`), so these convergence curves ARE the
distributed system's curves for any ``--dp-wire``.  Wire bytes in the
throughput model are reported per wire: ``psum`` is the i32-lane
collective at the same ring-allreduce physical convention as the fp32
row, ``ring`` is the exact packed-payload accounting of
`collectives.ring_wire_bytes`, and ``ring-sharded`` its
``sharded=True`` mode (reduce-scatter half only — the formulas
tests/test_hlo_cost.py pins against the traced HLO).  All rows count
gradient traffic only; parameter gathers (ZeRO-3) are common.

``--tiny --json out.json`` is the CI smoke configuration: fewer steps,
machine-readable output uploaded as a nightly artifact alongside the
quant-kernel bench.
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import finetune, tail_loss, write_csv
from benchmarks.throughput_model import (BANDWIDTHS, CFG, MACRO,
                                         throughput_seqs_per_s, _N)
from repro.core.aqsgd import CompressionConfig
from repro.core import collectives as C
from repro.core import grad_compress as GC
from repro.models import model as Mo

import jax


def main(steps: int = 50, tiny: bool = False,
         json_path: str | None = None) -> list:
    if tiny:
        steps = min(steps, 30)
    results = {"tiny": tiny, "steps": steps, "convergence": {},
               "throughput": {}}
    rows = []
    for mode, label in (("fp32", "FP32"),
                        ("aqsgd", "AQ-SGD fw3bw6 + grad4"),
                        ("directq", "DirectQ fw3bw6 + grad4")):
        dp = 0 if mode == "fp32" else 4
        losses, _ = finetune(mode, 3, 6, steps=steps, dp_grad_bits=dp,
                             dp_workers=2)
        tl = tail_loss(losses)
        rows.append((label, f"{tl:.4f}"))
        results["convergence"][label] = tl
        print(f"e2e_compression,{label},,{tl:.4f}")
    by = dict(rows)
    ok = float(by["AQ-SGD fw3bw6 + grad4"]) < \
        float(by["DirectQ fw3bw6 + grad4"])
    results["claim_aqsgd_beats_directq_with_gradcomp"] = bool(ok)
    print(f"e2e_compression,claim_aqsgd_beats_directq_with_gradcomp,,{ok}")
    write_csv("e2e_compression.csv", "method,final_loss", rows)

    # throughput: add the DP gradient allreduce wire to the model.
    # All rows use the same PHYSICAL per-worker convention: an i32/f32
    # allreduce rides a ring shipping ~2x its operand bytes (the fp32
    # row and the i32-lane "psum" wire both get that factor), while the
    # compressed ring's model (`collectives.ring_wire_bytes`: b-bit
    # code segments + packed code sums + f32 scale pmax, pinned to the
    # traced HLO by test_hlo_cost) already counts its 2(N-1) hops.
    params_shape = jax.eval_shape(
        lambda: Mo.init_params(CFG, jax.random.PRNGKey(0)))
    dp_workers = 2
    lay = GC.bucket_layout(params_shape)
    bucket = (lay.rows, lay.group_d)
    grad_fp32 = _N * 4 * 2
    # per-wire GRADIENT bytes only: every row excludes parameter
    # traffic (the ZeRO-3 per-layer weight gathers are common to all
    # wires; ring-sharded's updated-parameter all-gather replaces the
    # gradient all-gather and is the same ZeRO-3 class of traffic)
    grad_wire = {
        "psum": (lay.rows * lay.group_d * 4 + lay.rows * 4) * 2,
        "ring": C.ring_wire_bytes(bucket, 4, n=dp_workers),
        "ring-sharded": C.ring_wire_bytes(bucket, 4, n=dp_workers,
                                          sharded=True),
    }
    results["grad_wire_bytes"] = {
        "fp32": grad_fp32,
        "q4_psum": grad_wire["psum"],
        "q4_ring": grad_wire["ring"],
        "q4_ring_sharded": grad_wire["ring-sharded"]}
    trows = []
    for bname, bw in BANDWIDTHS.items():
        def step_time(cc, gbytes):
            act = MACRO / throughput_seqs_per_s(cc, bw)
            return act + gbytes * 8 / bw

        t_fp = step_time(CompressionConfig(mode="fp32"), grad_fp32)
        t_act = step_time(CompressionConfig(mode="aqsgd", fw_bits=3,
                                            bw_bits=6), grad_fp32)
        results["throughput"][bname] = {
            "fp32": MACRO / t_fp, "act_only": MACRO / t_act}
        for wire in ("psum", "ring", "ring-sharded"):
            t_all = step_time(CompressionConfig(mode="aqsgd", fw_bits=3,
                                                bw_bits=6),
                              grad_wire[wire])
            trows.append((bname, wire, f"{MACRO/t_fp:.2f}",
                          f"{MACRO/t_act:.2f}", f"{MACRO/t_all:.2f}",
                          f"{t_fp/t_all:.2f}x"))
            results["throughput"][bname][f"act_plus_grad_{wire}"] = \
                MACRO / t_all
            results["throughput"][bname][f"speedup_{wire}"] = t_fp / t_all
            print(f"e2e_throughput,{bname},wire={wire},"
                  f"fp32={MACRO/t_fp:.2f},act_only={MACRO/t_act:.2f},"
                  f"act+grad={MACRO/t_all:.2f},"
                  f"speedup={t_fp/t_all:.2f}x")
    write_csv("e2e_throughput.csv",
              "bandwidth,wire,fp32,act_only,act_plus_grad,speedup", trows)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke configuration (fewer steps)")
    ap.add_argument("--json", default=None,
                    help="also dump machine-readable results to this path")
    args = ap.parse_args()
    main(steps=args.steps, tiny=args.tiny, json_path=args.json)
