"""Fig. 5 reproduction: AQ-SGD combined with data-parallel gradient
compression ("end-to-end communication compression").

(a/b) convergence: AQ-SGD fw3 bw6 + 4-bit error-feedback model-gradient
compression must track FP32 where DirectQ+gradient compression degrades.
(c) throughput: with both activation and gradient wires compressed, the
modeled end-to-end speedup over no-compression grows beyond
activation-only compression (paper: up to 8.5x at 100 Mbps)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import finetune, tail_loss, write_csv
from benchmarks.throughput_model import (BANDWIDTHS, CFG, MACRO, MICRO, K,
                                         SEQ, FWD_MS, BWD_MS, _N,
                                         throughput_seqs_per_s)
from repro.core.aqsgd import CompressionConfig
from repro.core import quantization as Q


def main(steps: int = 50) -> list:
    rows = []
    for mode, label in (("fp32", "FP32"),
                        ("aqsgd", "AQ-SGD fw3bw6 + grad4"),
                        ("directq", "DirectQ fw3bw6 + grad4")):
        dp = 0 if mode == "fp32" else 4
        losses, _ = finetune(mode, 3, 6, steps=steps, dp_grad_bits=dp,
                             dp_workers=2)
        tl = tail_loss(losses)
        rows.append((label, f"{tl:.4f}"))
        print(f"e2e_compression,{label},,{tl:.4f}")
    by = dict(rows)
    ok = float(by["AQ-SGD fw3bw6 + grad4"]) < \
        float(by["DirectQ fw3bw6 + grad4"])
    print(f"e2e_compression,claim_aqsgd_beats_directq_with_gradcomp,,{ok}")
    write_csv("e2e_compression.csv", "method,final_loss", rows)

    # throughput: add the DP gradient allreduce wire to the model.
    # model gradient bytes per worker per step (ring allreduce ~ 2x size)
    grad_fp32 = _N * 4 * 2
    grad_q4 = int(_N * 0.5 * 2 + _N / CFG.d_model * 4 * 2)
    trows = []
    for bname, bw in BANDWIDTHS.items():
        def step_time(cc, gbytes):
            act = MACRO / throughput_seqs_per_s(cc, bw)
            return act + gbytes * 8 / bw

        t_fp = step_time(CompressionConfig(mode="fp32"), grad_fp32)
        t_act = step_time(CompressionConfig(mode="aqsgd", fw_bits=3,
                                            bw_bits=6), grad_fp32)
        t_all = step_time(CompressionConfig(mode="aqsgd", fw_bits=3,
                                            bw_bits=6), grad_q4)
        trows.append((bname, f"{MACRO/t_fp:.2f}", f"{MACRO/t_act:.2f}",
                      f"{MACRO/t_all:.2f}", f"{t_fp/t_all:.2f}x"))
        print(f"e2e_throughput,{bname},fp32={MACRO/t_fp:.2f},"
              f"act_only={MACRO/t_act:.2f},act+grad={MACRO/t_all:.2f},"
              f"speedup={t_fp/t_all:.2f}x")
    write_csv("e2e_throughput.csv",
              "bandwidth,fp32,act_only,act_plus_grad,speedup", trows)
    return rows


if __name__ == "__main__":
    main()
