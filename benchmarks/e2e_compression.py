"""Fig. 5 reproduction: AQ-SGD combined with data-parallel gradient
compression ("end-to-end communication compression").

(a/b) convergence: AQ-SGD fw3 bw6 + 4-bit error-feedback model-gradient
compression must track FP32 where DirectQ+gradient compression degrades.
(c) throughput: with both activation and gradient wires compressed, the
modeled end-to-end speedup over no-compression grows beyond
activation-only compression (paper: up to 8.5x at 100 Mbps).

The gradient wire measured here is the real fused path: the simulated
trainer routes ``dp_grad_bits`` through the bucketed error-feedback
codec of `core.grad_compress` (shared-scale fused codes-only quantize,
int32 code accumulation, fused dequant-mean) — bit-identical to the
codec shard_map wires (psum / ring / ring-sharded), so these
convergence curves ARE the distributed system's curves for any codec
``--dp-wire``.  Per-wire byte accounting comes from the wire
registry's uniform `WireSpec.wire_bytes` (`repro.comm.wires` — the
same models tests/test_hlo_cost.py pins against the traced HLO, for
EVERY registered DP wire including the fp16 passthrough), and the
``e2e_wire_bytes.csv`` artifact reports every plane — forward
activations, backward gradients, z-buffers, and each DP wire — from
that one accounting code, with a ``plane`` column.  Allreduce-class
rows (fp32 and the psum-lowered wires: i32-lane psum, fp16) carry the
2x physical ring convention on top of their lane bytes; the ring
wires' models already count their hops.  All rows count gradient traffic only; parameter
gathers (ZeRO-3) are common.

``--tiny --json out.json`` is the CI smoke configuration: fewer steps,
machine-readable output uploaded as a nightly artifact alongside the
quant-kernel bench.
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import (finetune, overlapped_ms, serialized_ms,
                               tail_loss, write_csv)
from benchmarks.throughput_model import (BANDWIDTHS, CFG, MACRO, MICRO,
                                         SEQ, throughput_seqs_per_s, _N)
from repro.comm import wires as W
from repro.core.aqsgd import CompressionConfig
from repro.core import grad_compress as GC
from repro.models import model as Mo

import jax


def main(steps: int = 50, tiny: bool = False,
         json_path: str | None = None) -> list:
    if tiny:
        steps = min(steps, 30)
    results = {"tiny": tiny, "steps": steps, "convergence": {},
               "throughput": {}}
    rows = []
    for mode, label in (("fp32", "FP32"),
                        ("aqsgd", "AQ-SGD fw3bw6 + grad4"),
                        ("directq", "DirectQ fw3bw6 + grad4")):
        dp = 0 if mode == "fp32" else 4
        losses, _ = finetune(mode, 3, 6, steps=steps, dp_grad_bits=dp,
                             dp_workers=2)
        tl = tail_loss(losses)
        rows.append((label, f"{tl:.4f}"))
        results["convergence"][label] = tl
        print(f"e2e_compression,{label},,{tl:.4f}")
    by = dict(rows)
    ok = float(by["AQ-SGD fw3bw6 + grad4"]) < \
        float(by["DirectQ fw3bw6 + grad4"])
    results["claim_aqsgd_beats_directq_with_gradcomp"] = bool(ok)
    print(f"e2e_compression,claim_aqsgd_beats_directq_with_gradcomp,,{ok}")
    write_csv("e2e_compression.csv", "method,final_loss", rows)

    # throughput: add the DP gradient allreduce wire to the model.
    # Per-wire bytes come from the registry's uniform `wire_bytes`
    # accounting (the SAME models the HLO regression pins exactly);
    # allreduce-class lanes (fp32, i32 psum) additionally carry the 2x
    # physical ring convention — the ring wires' models already count
    # their per-hop traffic.
    params_shape = jax.eval_shape(
        lambda: Mo.init_params(CFG, jax.random.PRNGKey(0)))
    dp_workers = 2
    dp_bits = 4
    lay = GC.bucket_layout(params_shape)
    bucket = (lay.rows, lay.group_d)
    grad_fp32 = _N * 4 * 2
    # per-wire GRADIENT bytes only: every row excludes parameter
    # traffic (the ZeRO-3 per-layer weight gathers are common to all
    # wires; ring-sharded's updated-parameter all-gather replaces the
    # gradient all-gather and is the same ZeRO-3 class of traffic)
    dp_wires = W.wire_names("dp-grad")
    # psum-lowered wires (WireSpec.psum_lowered): their registry model
    # counts the logical collective lanes (what the HLO pin measures),
    # so the 2x physical ring-allreduce convention applies on top —
    # exactly like the fp32 row.  The ring wires' models already count
    # their hops.  Keyed on registry metadata, so a newly registered
    # wire lands in the right class with no edit here.
    grad_wire = {}
    for name in dp_wires:
        spec = W.get_wire(name)
        b = spec.wire_bytes(bucket, dp_bits, dp_workers)
        grad_wire[name] = b * 2 if spec.psum_lowered else b
    results["grad_wire_bytes"] = {
        "fp32": grad_fp32,
        **{f"q{dp_bits}_{n.replace('-', '_')}": grad_wire[n]
           for n in dp_wires}}

    # every plane's bytes from the ONE accounting code (plane column):
    # activation planes per boundary per microbatch at the
    # throughput-model shape, DP wires per step for the whole bucket
    act_shape = (MICRO * SEQ, CFG.d_model)
    fw_spec = W.get_wire("ppermute", plane="fw-activation")
    bw_spec = W.get_wire("ppermute", plane="bw-gradient")
    zb_spec = W.get_wire("hbm", plane="z-buffer")
    prows = [
        ("fw-activation", "ppermute", 3,
         fw_spec.wire_bytes(act_shape, 3, 1)),
        ("bw-gradient", "ppermute", 6,
         bw_spec.wire_bytes(act_shape, 6, 1)),
        ("z-buffer", "hbm", 4, zb_spec.wire_bytes(act_shape, 4, 1)),
    ] + [("dp-grad", n, dp_bits,
          W.get_wire(n).wire_bytes(bucket, dp_bits, dp_workers))
         for n in dp_wires]
    write_csv("e2e_wire_bytes.csv", "plane,wire,bits,bytes",
              [(p, w, str(b), str(by)) for p, w, b, by in prows])
    results["wire_bytes_by_plane"] = [
        {"plane": p, "wire": w, "bits": b, "bytes": by}
        for p, w, b, by in prows]

    trows = []
    for bname, bw in BANDWIDTHS.items():
        def step_time(cc, gbytes):
            act = MACRO / throughput_seqs_per_s(cc, bw)
            return act + gbytes * 8 / bw

        t_fp = step_time(CompressionConfig(mode="fp32"), grad_fp32)
        t_act = step_time(CompressionConfig(mode="aqsgd", fw_bits=3,
                                            bw_bits=6), grad_fp32)
        results["throughput"][bname] = {
            "fp32": MACRO / t_fp, "act_only": MACRO / t_act}
        for wire in dp_wires:
            t_all = step_time(CompressionConfig(mode="aqsgd", fw_bits=3,
                                                bw_bits=6),
                              grad_wire[wire])
            trows.append((bname, "dp-grad", wire, f"{MACRO/t_fp:.2f}",
                          f"{MACRO/t_act:.2f}", f"{MACRO/t_all:.2f}",
                          f"{t_fp/t_all:.2f}x"))
            results["throughput"][bname][f"act_plus_grad_{wire}"] = \
                MACRO / t_all
            results["throughput"][bname][f"speedup_{wire}"] = t_fp / t_all
            print(f"e2e_throughput,{bname},wire={wire},"
                  f"fp32={MACRO/t_fp:.2f},act_only={MACRO/t_act:.2f},"
                  f"act+grad={MACRO/t_all:.2f},"
                  f"speedup={t_fp/t_all:.2f}x")
    write_csv("e2e_throughput.csv",
              "bandwidth,plane,wire,fp32,act_only,act_plus_grad,speedup",
              trows)

    # overlap-aware DP-wire cost model: per chunkable wire x bits x
    # bandwidth, the per-step gradient-collective time under the
    # monolithic serialized schedule (compute, THEN the whole wire)
    # vs the K-chunk double-buffered schedule (`--dp-chunks`), from
    # the ONE shared accounting in benchmarks/common.  The chunked
    # estimate must be STRICTLY below serialized whenever both sides
    # cost anything — asserted here for the acceptance bandwidths so
    # the artifact cannot silently regress into "chunking is free".
    chunkable = [n for n in dp_wires if W.get_wire(n).chunkable]
    cc_act = CompressionConfig(mode="aqsgd", fw_bits=3, bw_bits=6)
    OVERLAP_K = 4
    xrows = []
    results["overlap"] = []
    for bname, bw in BANDWIDTHS.items():
        comp_s = MACRO / throughput_seqs_per_s(cc_act, bw)
        for wire in chunkable:
            spec = W.get_wire(wire)
            for b in (2, 4, 8):
                wire_s = spec.wire_bytes(bucket, b, dp_workers) * 8 / bw
                ser = serialized_ms(comp_s, wire_s)
                ovl = overlapped_ms(comp_s, wire_s, OVERLAP_K)
                if bname == "100Mbps":
                    assert ovl < ser, (bname, wire, b, ovl, ser)
                xrows.append((bname, wire, str(b), str(OVERLAP_K),
                              f"{ser:.3f}", f"{ovl:.3f}",
                              f"{ser / ovl:.2f}x"))
                results["overlap"].append(
                    {"bandwidth": bname, "wire": wire, "bits": b,
                     "chunks": OVERLAP_K, "serialized_s": ser,
                     "overlapped_s": ovl})
                print(f"e2e_overlap,{bname},wire={wire},bits={b},"
                      f"K={OVERLAP_K},serialized={ser:.3f}s,"
                      f"overlapped={ovl:.3f}s")
    write_csv("e2e_overlap.csv",
              "bandwidth,wire,bits,chunks,serialized_s,overlapped_s,"
              "gain", xrows)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke configuration (fewer steps)")
    ap.add_argument("--json", default=None,
                    help="also dump machine-readable results to this path")
    args = ap.parse_args()
    main(steps=args.steps, tiny=args.tiny, json_path=args.json)
