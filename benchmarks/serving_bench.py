"""Serving throughput vs bandwidth vs KV precision.

The training-side Tables 2-3 accounting (`benchmarks.throughput_model`)
pointed at decode: per-token pipeline throughput under FP16 / DirectQ /
AQ-SGD-delta inter-stage hops, crossed with KV-cache precision.  The
decode hop ships ``(B, 1, d)`` per token per boundary — tiny, so slow
networks hurt decode latency far more than prefill — and the KV plane
sets how many concurrent requests fit HBM (slots scale ~``32/bits``).

All byte claims come from the registered wires' ``wire_bytes`` models
(the HLO-pinned ones); compute per token per stage is the same
v5e-roofline estimate the training table uses.  The bench asserts the
compressed hop is STRICTLY below the fp16 hop in modeled bytes/token —
the acceptance gate for the serving plane.

``--tiny --json out.json`` is the CI smoke configuration: it also runs
a real (smoke-config) decode loop per KV setting for a measured tok/s
column.
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import write_csv
from repro.configs.base import get_config
from repro.serving.delta import DeltaHopCodec
from repro.serving.kvcache import KVCodec

BANDWIDTHS = {            # bits/s
    "10Gbps": 10e9, "1Gbps": 1e9, "300Mbps": 300e6, "100Mbps": 100e6,
}
HOPS = [
    ("fp16", None),
    ("DirectQ 8", DeltaHopCodec(mode="directq", bits=8)),
    ("AQ-delta 8", DeltaHopCodec(mode="aqsgd", bits=8)),
    ("AQ-delta 4", DeltaHopCodec(mode="aqsgd", bits=4)),
]
KV_BITS = (0, 8, 4)

CFG = get_config("gpt2-xl-paper")
BATCH, K, HBM_GB = 8, 8, 16
_MFU = 0.40
TOK_MS = 2 * CFG.params_count() / K / (197e12 * _MFU) * 1e3


def hop_bytes(codec) -> int:
    """Modeled bytes for one token's hidden-state hop at one boundary."""
    if codec is None:                       # fp16 baseline wire
        return BATCH * CFG.d_model * 2
    return codec.hop_bytes(BATCH, CFG.d_model)


def tokens_per_s(codec, bw: float) -> float:
    """Sequential decode: each token crosses K-1 boundaries; hop latency
    does NOT overlap compute (the next stage is idle until it lands)."""
    hop_ms = hop_bytes(codec) * 8 / bw * 1e3
    return BATCH * 1e3 / (K * TOK_MS + (K - 1) * hop_ms)


def kv_tokens_per_slot(bits: int) -> tuple:
    """(bytes/token stored, max concurrent 8k-context requests/chip)."""
    codec = KVCodec(bits=bits)
    per_tok = codec.stored_bytes(
        (1, 1, CFG.num_kv_heads, CFG.head_dim)) * 2 * CFG.num_layers
    ctx_bytes = per_tok * 8192
    return per_tok, int(HBM_GB * 2 ** 30 * 0.5 // ctx_bytes)


def _measured_tiny(kv_bits: int) -> float:
    """Real smoke-config decode loop -> tok/s (CI sanity, not a claim)."""
    import time
    import jax
    import jax.numpy as jnp
    from repro.models import model as Mo
    from repro.serving.kvcache import quantize_caches

    cfg = get_config("gemma2-9b", smoke=True)
    params = Mo.init_params(cfg, jax.random.PRNGKey(0))
    codec = KVCodec(bits=kv_bits) if kv_bits else None
    caches = Mo.init_caches(cfg, 2, 24, jnp.float32)
    if codec is not None:
        caches = quantize_caches(cfg, caches, codec)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    logits, caches = Mo.forward_with_caches(
        params, cfg, toks, caches, logits_last_only=True, kv_codec=codec)
    step = jax.jit(lambda p, c, t: Mo.forward_with_caches(
        p, cfg, t, c, logits_last_only=True, kv_codec=codec))
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    logits, caches = step(params, caches, tok)     # compile
    n, t0 = 8, time.time()
    for _ in range(n):
        logits, caches = step(params, caches, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
    jax.block_until_ready(tok)
    return 2 * n / (time.time() - t0)


def main(tiny: bool = False, json_path: str | None = None) -> dict:
    results: dict = {"tiny": tiny, "hop_bytes": {}, "kv": {},
                     "throughput": {}}
    print(f"# GPT2-XL decode, batch {BATCH}, {K} stages: "
          f"{TOK_MS * K:.3f}ms compute/token")

    fp16 = hop_bytes(None)
    for name, codec in HOPS:
        hb = hop_bytes(codec)
        results["hop_bytes"][name] = hb
        print(f"hop,{name},{hb} B/token/boundary")
        if codec is not None:
            assert hb < fp16, (name, hb, fp16)   # the acceptance gate

    header = ["bandwidth"] + [n for n, _ in HOPS]
    rows = []
    for bname, bw in BANDWIDTHS.items():
        row = [bname] + [f"{tokens_per_s(c, bw):.2f}" for _, c in HOPS]
        rows.append(row)
        results["throughput"][bname] = dict(zip(header[1:], row[1:]))
        print("tokens_per_s," + ",".join(row))
    write_csv("serving_throughput.csv", ",".join(header), rows)

    kv_rows = []
    for bits in KV_BITS:
        per_tok, slots = kv_tokens_per_slot(bits)
        entry = {"bytes_per_token": per_tok, "requests_8k_ctx": slots}
        if tiny:
            entry["measured_tok_s"] = round(_measured_tiny(bits), 2)
        results["kv"][str(bits)] = entry
        kv_rows.append((bits or "fp32", per_tok, slots))
        print(f"kv,{bits or 'fp32'},{per_tok} B/token,"
              f"{slots} reqs@8k" +
              (f",{entry['measured_tok_s']} tok/s measured"
               if tiny else ""))
    write_csv("serving_kv.csv", "kv_bits,bytes_per_token,requests_8k_ctx",
              kv_rows)

    slow = tokens_per_s(HOPS[-1][1], BANDWIDTHS["100Mbps"])
    base = tokens_per_s(None, BANDWIDTHS["100Mbps"])
    print(f"tokens_per_s,speedup_delta4_vs_fp16_100Mbps,"
          f"{slow / base:.2f}x")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {json_path}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    main(tiny=args.tiny, json_path=args.json)
