"""Benchmark driver — one module per paper table/figure.

  convergence       Fig. 1a / Fig. 3   (loss vs steps per scheme)
  delta_magnitude   Fig. 1b            (|activation| vs |delta|)
  throughput_model  Tables 2-3 / Fig. 4 (throughput vs bandwidth)
  e2e_compression   Fig. 5             (+ DP gradient compression)
  ablations         Fig. 9             (stages / bits / buffer precision)
  storage_cost      §3.3 / App. G      (buffer storage, prefetch hiding)
  quant_kernel      (ours)             (boundary codec microbench)

Prints ``name,...,derived`` CSV lines; full tables land in results/*.csv.
Roofline tables come from ``python -m repro.launch.dryrun`` (see
EXPERIMENTS.md §Dry-run / §Roofline).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names")
    ap.add_argument("--steps", type=int, default=50,
                    help="fine-tune steps per convergence cell")
    args = ap.parse_args()

    from benchmarks import (ablations, convergence, delta_magnitude,
                            e2e_compression, quant_kernel, storage_cost,
                            throughput_model)
    all_benches = [
        ("convergence", lambda: convergence.main(args.steps)),
        ("delta_magnitude", lambda: delta_magnitude.main()),
        ("throughput_model", throughput_model.main),
        ("e2e_compression", lambda: e2e_compression.main(args.steps)),
        ("ablations", lambda: ablations.main(args.steps)),
        ("storage_cost", storage_cost.main),
        ("quant_kernel", quant_kernel.main),
    ]
    only = set(args.only.split(",")) if args.only else None
    for name, fn in all_benches:
        if only and name not in only:
            continue
        print(f"\n===== {name} =====")
        t0 = time.time()
        fn()
        print(f"# {name} done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
