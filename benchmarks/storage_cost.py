"""§3.3 / Appendix G reproduction: AQ-SGD's storage-for-communication
trade, across the paper's setting and every assigned architecture.

Also models the prefetch-hiding claim: loading m(ξ) from host DRAM/SSD
is hidden under the stage's forward compute when
t_load < t_forward (per microbatch)."""
from __future__ import annotations

from benchmarks.common import write_csv
from repro.configs.base import ARCHS, get_config
from repro.core import aqsgd
from repro.core.aqsgd import CompressionConfig

# paper's LM corpus scale: WikiText2, 2M tokens at seq 1024
N_SAMPLES, SEQ, K = 2_000_000 // 1024, 1024, 8
DRAM_BW, SSD_BW = 50e9, 3e9            # bytes/s
V5E_FLOPS, MFU = 197e12, 0.4


def main() -> list:
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        d = cfg.d_model
        for z, label in ((0, "fp32"), (8, "z8"), (4, "z4")):
            cc = CompressionConfig(mode="aqsgd", buffer_bits=z)
            nbytes = aqsgd.buffer_nbytes(cc, K - 1, N_SAMPLES, SEQ, d)
            rows.append((arch, label, f"{nbytes/1e9:.1f}"))
        # prefetch hiding: per microbatch (1 sample), load vs fwd compute
        load_ms = SEQ * d * 4 / SSD_BW * 1e3
        fwd_ms = 2 * cfg.active_params_count() / K * SEQ \
            / (V5E_FLOPS * MFU) * 1e3
        hidden = load_ms < fwd_ms
        print(f"storage,{arch},buffer_fp32_GB="
              f"{float(rows[-3][2]):.1f},ssd_load={load_ms:.1f}ms,"
              f"fwd={fwd_ms:.1f}ms,hidden={hidden}")
    write_csv("storage_cost.csv", "arch,buffer_precision,total_GB", rows)
    # the paper's GPT2-XL example: ~0.1 TB per boundary-side at fp32
    gpt2 = [r for r in rows if r[0] == "gpt2-xl-paper" and r[1] == "fp32"]
    print(f"storage,paper_gpt2xl_fp32_buffers,,{gpt2[0][2]}GB "
          f"(paper §3.3 cites ~1TB across machines+both sides)")
    return rows


if __name__ == "__main__":
    main()
