"""Kernel microbench: the AQ-SGD boundary codec, fused vs unfused.

Wall-clock on this container measures the *interpret-mode / XLA-CPU*
path, so the numbers that matter for TPU are the analytic ones: fused
HBM traffic vs unfused, and wire-compression ratios.  We report both,
for each side of the boundary:

* ``unfused_*``  — the legacy chain (quantize → pack / unpack →
  dequantize → accumulate) as separate XLA ops, ~6 HBM round-trips;
* ``fused_*``    — the Pallas kernels behind `repro.core.boundary`
  (one pass per side; interpret mode on CPU).

``--tiny --json out.json`` is the CI smoke configuration: small shapes,
machine-readable output uploaded as a nightly artifact so the fused
hot-path numbers land in the bench trajectory.
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import write_csv
from repro.core import quantization as Q
from repro.kernels import ops


def _time(f, *a, n=5):
    jax.tree.leaves(f(*a))[0].block_until_ready()          # compile
    t0 = time.time()
    for _ in range(n):
        r = f(*a)
        jax.tree.leaves(r)[0].block_until_ready()
    return (time.time() - t0) / n * 1e6


@functools.partial(jax.jit, static_argnames=("bits",))
def _unfused_sender(a, m, *, bits):
    """The pre-refactor boundary sender: each step a separate XLA op."""
    delta = a - m
    codes, scale = Q.quantize(delta, bits, stochastic=False)
    packed = Q.pack_codes(codes, bits)
    m_new = m + Q.dequantize(codes, scale, bits)
    return packed, scale, m_new


@functools.partial(jax.jit, static_argnames=("bits",))
def _unfused_receiver(packed, scale, m, *, bits):
    d = m.shape[-1]
    return m + Q.dequantize(Q.unpack_codes(packed, bits, d), scale, bits)


def main(tiny: bool = False, json_path: str | None = None) -> list:
    rows = []
    r, d = (256, 512) if tiny else (4096, 4096)
    reps = 2 if tiny else 5
    a = jax.random.normal(jax.random.PRNGKey(0), (r, d))
    m = a + 0.01 * jax.random.normal(jax.random.PRNGKey(1), (r, d))
    results = {"shape": [r, d], "tiny": tiny, "bench": {}}

    for bits in (2, 4, 8):
        us_s_un = _time(lambda: _unfused_sender(a, m, bits=bits), n=reps)
        us_s_fu = _time(lambda: ops.boundary_compress(a, m, bits=bits),
                        n=reps)
        packed, scale, _ = ops.boundary_compress(a, m, bits=bits)
        us_r_un = _time(
            lambda: _unfused_receiver(packed, scale, m, bits=bits), n=reps)
        us_r_fu = _time(
            lambda: ops.boundary_decompress(packed, scale, m, bits=bits),
            n=reps)

        raw = r * d * 4
        wire = Q.wire_bytes((r, d), bits)
        # fused kernel: read a+m, write packed+scale+m_new
        fused_traffic = raw * 2 + wire + raw
        # unfused chain: sub, abs-max, div, round, pack, dequant, add —
        # each materializes an (r, d) intermediate
        unfused_traffic = raw * 2 + 6 * raw + wire
        stats = {
            "unfused_sender_us": us_s_un, "fused_sender_us": us_s_fu,
            "unfused_receiver_us": us_r_un, "fused_receiver_us": us_r_fu,
            "wire_ratio": raw / wire,
            "hbm_traffic_saving": unfused_traffic / fused_traffic,
        }
        results["bench"][f"b{bits}"] = stats
        rows.append((f"sender_b{bits}", f"{us_s_un:.0f}", f"{us_s_fu:.0f}",
                     f"ratio={raw/wire:.1f}x",
                     f"traffic_saving={unfused_traffic/fused_traffic:.2f}x"))
        rows.append((f"receiver_b{bits}", f"{us_r_un:.0f}",
                     f"{us_r_fu:.0f}", "", ""))
        print(f"quant_kernel,b{bits}: sender unfused {us_s_un:.0f}us "
              f"fused {us_s_fu:.0f}us | receiver unfused {us_r_un:.0f}us "
              f"fused {us_r_fu:.0f}us | wire_ratio={raw/wire:.1f}x "
              f"hbm_saving={unfused_traffic/fused_traffic:.2f}x "
              f"(fused = interpret mode on CPU; analytic columns are the "
              f"TPU story)")

    write_csv("quant_kernel.csv",
              "name,unfused_us,fused_us,wire_ratio,traffic", rows)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke configuration (small shapes)")
    ap.add_argument("--json", default=None,
                    help="also dump machine-readable results to this path")
    args = ap.parse_args()
    main(tiny=args.tiny, json_path=args.json)
