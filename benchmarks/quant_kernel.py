"""Kernel microbench: the AQ-SGD boundary codec.

Wall-clock on this container measures the *interpret-mode / XLA-CPU*
path, so the numbers that matter for TPU are the analytic ones: fused
HBM traffic vs unfused, and wire-compression ratios.  We report both.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_csv
from repro.core import quantization as Q
from repro.kernels import ops


def _time(f, *a, n=5):
    f(*a)[0].block_until_ready() if isinstance(f(*a), tuple) else None
    t0 = time.time()
    for _ in range(n):
        r = f(*a)
        jax.tree.leaves(r)[0].block_until_ready()
    return (time.time() - t0) / n * 1e6


def main() -> list:
    rows = []
    r, d = 4096, 4096
    a = jax.random.normal(jax.random.PRNGKey(0), (r, d))
    m = a + 0.01 * jax.random.normal(jax.random.PRNGKey(1), (r, d))

    import functools

    @functools.partial(jax.jit, static_argnames=("bits",))
    def xla_codec(a, m, *, bits):
        codes, scale = Q.quantize(a - m, bits, stochastic=False)
        return Q.pack_codes(codes, bits), scale

    for bits in (2, 4, 8):
        us_xla = _time(lambda: xla_codec(a, m, bits=bits))
        rows.append((f"xla_codec_b{bits}", f"{us_xla:.0f}", "", ""))
        print(f"quant_kernel,xla_codec_b{bits},{us_xla:.0f}us,"
              f"(XLA-CPU reference path)")
    for bits in (2, 4, 8):
        us = _time(lambda: ops.boundary_compress(a, m, bits=bits), n=2)
        raw = r * d * 4
        wire = Q.wire_bytes((r, d), bits)
        # fused kernel: read a+m, write packed+scale+m_new
        fused_traffic = raw * 2 + wire + raw
        # unfused chain: sub, abs-max, div, round, pack, dequant, add —
        # each materializes an (r, d) intermediate
        unfused_traffic = raw * 2 + 6 * raw + wire
        rows.append((f"boundary_compress_b{bits}", f"{us:.0f}",
                     f"ratio={raw/wire:.1f}x",
                     f"traffic_saving={unfused_traffic/fused_traffic:.2f}x"))
        print(f"quant_kernel,boundary_compress_b{bits},{us:.0f}us,"
              f"wire_ratio={raw/wire:.1f}x,"
              f"fused_traffic_saving={unfused_traffic/fused_traffic:.2f}x")
    write_csv("quant_kernel.csv", "name,us_per_call,wire_ratio,traffic",
              rows)
    return rows


if __name__ == "__main__":
    main()
