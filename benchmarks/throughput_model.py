"""Tables 2-3 / Fig. 4 reproduction: training throughput vs network
bandwidth under FP32 / DirectQ / AQ-SGD wire formats.

No slow network exists in this container, so this is the paper's own
accounting executed against OUR system's numbers: per-microbatch compute
time comes from the dry-run roofline of the paper's GPT2-XL config on
one v5e pipeline stage; per-microbatch communication time is the exact
wire payload (core.quantization.wire_bytes — what ppermute carries)
divided by bandwidth.  Compute/communication overlap (the paper's
observation) means step time ~ max(comp, comm) per tick.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import hidden_ms, serialized_ms, write_csv
from repro.configs.base import get_config
from repro.core.aqsgd import CompressionConfig

BANDWIDTHS = {            # bits/s
    "10Gbps": 10e9, "1Gbps": 1e9, "500Mbps": 500e6,
    "300Mbps": 300e6, "100Mbps": 100e6,
}
SETTINGS = [
    ("FP32", CompressionConfig(mode="fp32")),
    ("DirectQ fw3 bw6", CompressionConfig(mode="directq", fw_bits=3,
                                          bw_bits=6)),
    ("DirectQ fw4 bw8", CompressionConfig(mode="directq", fw_bits=4,
                                          bw_bits=8)),
    ("AQ-SGD fw3 bw6", CompressionConfig(mode="aqsgd", fw_bits=3,
                                         bw_bits=6)),
    ("AQ-SGD fw4 bw8", CompressionConfig(mode="aqsgd", fw_bits=4,
                                         bw_bits=8)),
]

# paper's LM setting: GPT2-XL, seq 1024, micro-batch 1, K=8 stages
CFG = get_config("gpt2-xl-paper")
SEQ, MICRO, K, MACRO = 1024, 1, 8, 32

# per-stage per-microbatch compute on a v5e chip: 6·N·tokens/K fwd+bwd
# FLOPs at a conservative 40% MFU (v5e 197 TFLOP/s bf16).
_N = CFG.params_count()
_FWD_FLOPS = 2 * _N * SEQ * MICRO / K
_MFU = 0.40
FWD_MS = _FWD_FLOPS / (197e12 * _MFU) * 1e3
BWD_MS = 2 * FWD_MS


def _wire_ms(cc: CompressionConfig, bw_bits_per_s: float):
    """(fw_ms, bw_ms) per boundary per microbatch."""
    shape = (MICRO * SEQ, CFG.d_model)
    fw = cc.fw_wire_bytes(shape) * 8 / bw_bits_per_s * 1e3
    bw = cc.bw_wire_bytes(shape) * 8 / bw_bits_per_s * 1e3
    return fw, bw


def throughput_seqs_per_s(cc: CompressionConfig, bw: float,
                          overlap: bool = True) -> float:
    """Modeled GPipe throughput: M microbatches, K stages, fwd and bwd
    phases.  ``overlap=True`` (the paper's observation, and the
    pipeline plane's pre-posted next-tick ppermute) hides comm under
    compute (`benchmarks.common.hidden_ms`); ``overlap=False`` is the
    serialized estimate (`serialized_ms`) — the same two accounting
    code paths `benchmarks/e2e_compression.py` uses for its overlap
    CSV, so the estimates cannot drift apart."""
    fw_ms, bw_ms = _wire_ms(cc, bw)
    tick = hidden_ms if overlap else serialized_ms
    m = MACRO // MICRO
    step_ms = (m + K - 1) * (tick(FWD_MS, fw_ms) + tick(BWD_MS, bw_ms))
    return MACRO / (step_ms / 1e3)


def main() -> list:
    rows = []
    print(f"# GPT2-XL (paper cfg): N={_N/1e9:.2f}B params, fwd "
          f"{FWD_MS:.0f}ms bwd {BWD_MS:.0f}ms per stage-microbatch "
          f"(v5e @ {_MFU:.0%} MFU)")
    header = ["bandwidth"] + [n for n, _ in SETTINGS]
    for bname, bw in BANDWIDTHS.items():
        row = [bname]
        for name, cc in SETTINGS:
            row.append(f"{throughput_seqs_per_s(cc, bw):.2f}")
        rows.append(row)
        print("throughput," + ",".join(row))
    write_csv("throughput.csv", ",".join(header), rows)

    # overlap-aware vs serialized pipeline estimate per setting x
    # bandwidth (the same hidden_ms/serialized_ms accounting the e2e
    # benchmark's chunked-wire CSV uses)
    orows = []
    for bname, bw in BANDWIDTHS.items():
        for name, cc in SETTINGS:
            hid = throughput_seqs_per_s(cc, bw)
            ser = throughput_seqs_per_s(cc, bw, overlap=False)
            orows.append((bname, name, f"{hid:.2f}", f"{ser:.2f}",
                          f"{hid / ser:.2f}x"))
    write_csv("throughput_overlap.csv",
              "bandwidth,setting,hidden_seqs_per_s,"
              "serialized_seqs_per_s,overlap_gain", orows)

    # Table 3: per-microbatch comp/comm breakdown for AQ-SGD fw4 bw8
    cc = SETTINGS[-1][1]
    rows3 = []
    for bname in ("500Mbps", "300Mbps", "200Mbps", "100Mbps"):
        bw = {"200Mbps": 200e6}.get(bname, BANDWIDTHS.get(bname))
        fw_ms, bw_ms = _wire_ms(cc, bw)
        rows3.append((bname, f"{FWD_MS:.1f}", f"{fw_ms:.1f}",
                      f"{BWD_MS:.1f}", f"{bw_ms:.1f}"))
        print(f"breakdown,{bname},fwd_comp={FWD_MS:.1f}ms,"
              f"fwd_comm={fw_ms:.1f}ms,bwd_comp={BWD_MS:.1f}ms,"
              f"bwd_comm={bw_ms:.1f}ms")
    write_csv("breakdown.csv",
              "bandwidth,fwd_comp_ms,fwd_comm_ms,bwd_comp_ms,bwd_comm_ms",
              rows3)

    # headline speedups (Fig. 4 structure)
    for bname in ("100Mbps", "300Mbps"):
        bw = BANDWIDTHS[bname]
        fp = throughput_seqs_per_s(SETTINGS[0][1], bw)
        aq = throughput_seqs_per_s(SETTINGS[-1][1], bw)
        print(f"throughput,speedup_aqsgd_vs_fp32_{bname},,"
              f"{aq / fp:.2f}x")
    slow = throughput_seqs_per_s(SETTINGS[-1][1], BANDWIDTHS["100Mbps"])
    fast = throughput_seqs_per_s(SETTINGS[-1][1], BANDWIDTHS["10Gbps"])
    print(f"throughput,aqsgd_slowdown_10Gbps_to_100Mbps,,"
          f"{fast / slow:.2f}x  (paper observed ~1.18x on V100s: their "
          f"per-stage compute is ~9x slower than v5e, so compressed comm "
          f"hid under compute; at TPU speeds AQ-SGD keeps training "
          f"compute-bound down to ~1 Gbps — see EXPERIMENTS.md)")
    # at what bandwidth does AQ-SGD stay compute-bound on v5e?
    for bname, bw in BANDWIDTHS.items():
        cc = SETTINGS[-1][1]
        fw_ms, bw_ms = _wire_ms(cc, bw)
        if fw_ms <= FWD_MS and bw_ms <= BWD_MS:
            print(f"throughput,aqsgd_compute_bound_down_to,,{bname}")
    return rows


if __name__ == "__main__":
    main()
