"""Shared benchmark scaffolding.

All convergence-style benchmarks use the paper's setting: a pre-trained
base model (cached to results/) is *fine-tuned* under each compression
scheme.  The model is a reduced GPT-2 (the paper's family) sized so a
full benchmark suite completes on one CPU core; the claims being checked
are *relative* (AQ-SGD vs DirectQ vs FP32), which transfer across scale —
the paper itself shows larger models tolerate compression better (§H.5).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.comm import CommConfig
from repro.configs.base import get_config
from repro.core.aqsgd import CompressionConfig
from repro.data.pipeline import Dataset, DatasetConfig
from repro.models import model as Mo
from repro.optim.adamw import AdamWConfig
from repro.training import simulated as sim

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")
os.makedirs(RESULTS, exist_ok=True)

MCFG = get_config("gpt2-xl-paper", smoke=True).with_(num_layers=4)
PRETRAIN_DS = DatasetConfig(num_samples=64, seq_len=64, vocab_size=512,
                            seed=3)
FINETUNE_DS = DatasetConfig(num_samples=48, seq_len=64, vocab_size=512,
                            seed=11)
BATCH = 8


def base_params(pretrain_steps: int = 120):
    """Train (once) and cache the 'foundation model' the benchmarks
    fine-tune."""
    path = os.path.join(RESULTS, "base_params.npz")
    like = Mo.init_params(MCFG, jax.random.PRNGKey(0))
    if os.path.exists(path):
        try:
            return ckpt.restore(path, like)
        except Exception:                     # stale cache
            os.remove(path)
    tcfg = sim.SimTrainConfig(
        num_stages=1,
        comm=CommConfig.from_legacy(CompressionConfig(mode="fp32")),
        optimizer=AdamWConfig(lr=2e-3, warmup_steps=10,
                              total_steps=pretrain_steps,
                              schedule="constant"))
    state, losses = sim.train(MCFG, tcfg, Dataset(PRETRAIN_DS),
                              num_steps=pretrain_steps, batch_size=BATCH,
                              key=jax.random.PRNGKey(0))
    print(f"# pretrained base: loss {losses[0]:.3f} -> "
          f"{np.mean(losses[-5:]):.3f}")
    ckpt.save(path, state["params"])
    return state["params"]


def finetune(mode: str, fw: int = 4, bw: int = 8, *, steps: int = 60,
             stages: int = 4, buffer_bits: int = 0, dp_grad_bits: int = 0,
             dp_workers: int = 1, lr: float = 3e-4, seed: int = 0,
             params=None):
    """Fine-tune under a compression scheme; returns (losses, seconds)."""
    tcfg = sim.SimTrainConfig(
        num_stages=stages,
        comm=CommConfig.from_legacy(
            CompressionConfig(mode=mode, fw_bits=fw, bw_bits=bw,
                              buffer_bits=buffer_bits),
            dp_grad_bits=dp_grad_bits),
        optimizer=AdamWConfig(lr=lr, warmup_steps=5, total_steps=steps,
                              schedule="constant"),
        dp_workers=dp_workers)
    t0 = time.time()
    _, losses = sim.train(MCFG, tcfg, Dataset(FINETUNE_DS),
                          num_steps=steps, batch_size=BATCH,
                          key=jax.random.PRNGKey(seed),
                          initial_params=params if params is not None
                          else base_params())
    return losses, time.time() - t0


def tail_loss(losses, k: int = 8) -> float:
    return float(np.mean(losses[-k:]))


# ---------------------------------------------------------------------------
# the ONE timing-model code path shared by benchmarks/throughput_model
# and benchmarks/e2e_compression: serialized, fully-hidden, and K-chunk
# double-buffered tick costs (same units in as out)
# ---------------------------------------------------------------------------

def serialized_ms(compute_ms: float, wire_ms: float) -> float:
    """Tick cost with NO compute/communication overlap: the wire waits
    for compute and compute waits for the wire."""
    return compute_ms + wire_ms


def hidden_ms(compute_ms: float, wire_ms: float) -> float:
    """Tick cost with comm fully hidden under compute (the paper's
    overlap observation, and the K -> inf limit of `overlapped_ms`):
    whichever side is longer sets the tick."""
    return max(compute_ms, wire_ms)


def overlapped_ms(compute_ms: float, wire_ms: float,
                  chunks: int = 1) -> float:
    """Tick cost under the K-chunk double-buffered schedule (the
    ``--dp-chunks`` wire): the payload moves in K slices and slice
    ``k+1``'s compute overlaps slice ``k``'s flight, so only the first
    compute slice and the last wire slice serialize —

        C/K + W/K + (K-1) * max(C, W)/K

    ``chunks <= 1`` degenerates to `serialized_ms` exactly (the
    monolithic schedule), and the limit K -> inf is `hidden_ms`.  For
    K > 1 with C > 0 and W > 0 this is STRICTLY below serialized —
    the acceptance gate benchmarks/e2e_compression.py asserts."""
    if chunks <= 1:
        return serialized_ms(compute_ms, wire_ms)
    return (compute_ms + wire_ms
            + (chunks - 1) * hidden_ms(compute_ms, wire_ms)) / chunks


def write_csv(name: str, header: str, rows: list):
    path = os.path.join(RESULTS, name)
    with open(path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    print(f"# wrote {path}")
