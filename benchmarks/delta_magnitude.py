"""Fig. 1b reproduction: average |activation| vs average |activation
delta| for the same samples across epochs.

The paper's motivating observation: deltas shrink as training
stabilizes, so quantizing deltas (AQ-SGD) sees a much smaller dynamic
range than quantizing activations (DirectQ)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (BATCH, FINETUNE_DS, MCFG, base_params,
                               write_csv)
from repro.comm import CommConfig
from repro.core.aqsgd import CompressionConfig
from repro.data.pipeline import Dataset
from repro.models import model as Mo
from repro.optim.adamw import AdamWConfig
from repro.training import simulated as sim


def main(epochs: int = 6) -> list:
    ds = Dataset(FINETUNE_DS)
    tcfg = sim.SimTrainConfig(
        num_stages=2,
        comm=CommConfig.from_legacy(CompressionConfig(mode="fp32")),
        optimizer=AdamWConfig(lr=3e-4, warmup_steps=5, total_steps=10_000,
                              schedule="constant"))
    state = sim.init_train_state(MCFG, tcfg, ds.num_samples,
                                 FINETUNE_DS.seq_len, jax.random.PRNGKey(0))
    state["params"] = base_params()

    @jax.jit
    def boundary_act(params, batch):
        """activation at the single stage boundary for a batch."""
        h = Mo.embed_tokens(params, MCFG, batch["tokens"])
        pos = jnp.broadcast_to(jnp.arange(h.shape[1], dtype=jnp.int32),
                               h.shape[:2])

        def bfn(st, hh, i):
            return st + (hh,), hh
        h2, _, bstate = Mo.trunk_forward(params, MCFG, h, pos,
                                         num_stages=2, boundary_fn=bfn,
                                         boundary_state=())
        return bstate[0]

    prev = {}
    rows = []
    key = jax.random.PRNGKey(1)
    step = 0
    for ep in range(epochs):
        act_mag, delta_mag, nb = 0.0, 0.0, 0
        for batch in ds.epoch(BATCH, shuffle=False):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            act = np.asarray(boundary_act(state["params"], batch))
            ids = tuple(np.asarray(batch["sample_ids"]))
            act_mag += float(np.mean(np.abs(act)))
            if ids in prev:
                delta_mag += float(np.mean(np.abs(act - prev[ids])))
                nb += 1
            prev[ids] = act
            state, _ = sim.train_step(
                state, batch, jax.random.fold_in(key, step),
                mcfg=MCFG, tcfg=tcfg)
            step += 1
        n_batches = ds.num_samples // BATCH
        row = (ep, act_mag / n_batches,
               delta_mag / nb if nb else float("nan"))
        rows.append(row)
        print(f"delta_magnitude,epoch{ep},|a|={row[1]:.4f},"
              f"|delta|={row[2]:.4f}")
    write_csv("delta_magnitude.csv", "epoch,act_mag,delta_mag",
              [(r[0], f"{r[1]:.5f}", f"{r[2]:.5f}") for r in rows])
    # claim: by the last epoch, deltas are much smaller than activations
    last = rows[-1]
    print(f"delta_magnitude,claim_delta_much_smaller,,"
          f"{last[2] < 0.5 * last[1]}")
    return rows


if __name__ == "__main__":
    main()
