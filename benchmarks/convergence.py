"""Fig. 1a / Fig. 3 reproduction: fine-tuning convergence under
FP32 / DirectQ / AQ-SGD at aggressive bit widths.

Paper claim being validated: AQ-SGD tracks FP32 at fw2-4 bits while
DirectQ converges to a clearly worse loss (or diverges)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import finetune, tail_loss, write_csv


SETTINGS = [("fw2 bw4", 2, 4), ("fw3 bw6", 3, 6), ("fw4 bw8", 4, 8)]


def main(steps: int = 60) -> list:
    rows = []
    curves = {}
    losses, secs = finetune("fp32", steps=steps)
    curves["fp32"] = losses
    fp = tail_loss(losses)
    rows.append(("fp32", "-", f"{fp:.4f}", f"{secs:.1f}"))
    print(f"convergence,fp32,-,{fp:.4f}")
    for label, fw, bw in SETTINGS:
        for mode in ("directq", "aqsgd"):
            losses, secs = finetune(mode, fw, bw, steps=steps)
            curves[f"{mode} {label}"] = losses
            tl = tail_loss(losses)
            rows.append((mode, label, f"{tl:.4f}", f"{secs:.1f}"))
            print(f"convergence,{mode},{label},{tl:.4f}")
    write_csv("convergence.csv", "method,bits,final_loss,seconds", rows)
    # loss curves for the figure
    n = max(len(v) for v in curves.values())
    cols = sorted(curves)
    write_csv("convergence_curves.csv", "step," + ",".join(cols),
              [[i] + [f"{curves[c][i]:.4f}" if i < len(curves[c]) else ""
                      for c in cols] for i in range(n)])

    # the paper's qualitative ordering must hold at every bit width
    by = {(r[0], r[1]): float(r[2]) for r in rows}
    ok = all(by[("aqsgd", lab)] < by[("directq", lab)]
             for lab, _, _ in SETTINGS)
    gap = all(abs(by[("aqsgd", lab)] - fp)
              < abs(by[("directq", lab)] - fp) for lab, _, _ in SETTINGS)
    print(f"convergence,claim_aqsgd_beats_directq,,{ok}")
    print(f"convergence,claim_aqsgd_closer_to_fp32,,{gap}")
    return rows


if __name__ == "__main__":
    main()
