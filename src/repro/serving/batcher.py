"""Minimal continuous batching over the compressed serving plane.

One fixed pool of ``num_slots`` cache rows (the "pages" — each request
owns exactly one row of every cache leaf for its lifetime) fed by a
FIFO of requests.  The decode step is compiled ONCE for the static
shape ``(num_slots, 1)`` and every iteration advances all slots
together; admission and eviction are host-side slot bookkeeping, never
a recompile.

State machine (per request)::

    PENDING --admit (free slot: B=1 exact-length prefill,
            |        write row into the pool, emit first token)
            v
    ACTIVE --batched decode step each tick, one token per tick
            |
            +--EOS sampled, or max_new_tokens reached
            v
    DONE   (slot freed, next PENDING request admitted)

Mixed lengths: each slot carries its own position in a ``(num_slots,)``
``pos`` vector, and the pooled step `jax.vmap`s the model's single-row
decode over it — rows at different depths attend over their own valid
prefix only.  Prefill compiles per UNIQUE prompt length (B=1, exact
length, no padding); serving a stream with many distinct lengths wants
length bucketing on top, which is out of scope here.

Compression hooks: a `serving.kvcache.KVCodec` swaps the pooled cache
to the quantized layout, and a `serving.delta.DeltaHopCodec` +
``num_stages`` routes every hidden-state hop between stage groups
through the delta codec (reference buffers live in the pool as
``hop_m`` and are evicted/re-prefilled with their slot).

Decoding is greedy (argmax) — what the fp32-vs-quantized equivalence
gate in tests/test_serving.py compares token-for-token.

Fault isolation (ISSUE 8): because the pooled step is a `jax.vmap`
over rows, slots are computationally independent — a poisoned row
CANNOT leak into its neighbors.  The batcher makes that operational:
a `repro.comm.faults.FaultPlan` injects kv-plane corruption into one
active slot's cache at a chosen tick, and the slot guard
(`faults.slot_flags` over the pool, plus an admission check on every
prefill row) evicts the poisoned request to ``DONE`` with
``req.error`` set — surviving slots' token streams stay bit-identical
to an uninjected run (gated by tests/test_faults.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import faults as F
from repro.models import model as Mo
from repro.serving.delta import DeltaHopCodec
from repro.serving.kvcache import KVCodec, quantize_caches

PENDING, ACTIVE, DONE = "PENDING", "ACTIVE", "DONE"


@dataclasses.dataclass
class ServeRequest:
    """One prompt in flight; ``tokens`` accumulates greedy output.
    ``error`` is empty for a clean completion; a request evicted by
    the slot guard lands in ``DONE`` with the structured fault text
    (plane/wire/tick) here instead of poisoning its neighbors."""
    prompt: list
    max_new_tokens: int = 16
    tokens: list = dataclasses.field(default_factory=list)
    state: str = PENDING
    slot: int = -1
    error: str = ""


class ContinuousBatcher:
    """Paged per-request cache slots + a single static-shape decode step.

    ``kv_codec``/``hop_codec``/``num_stages`` default to the
    uncompressed single-stage baseline; ``eos_id=None`` disables EOS
    eviction (requests run to ``max_new_tokens``).

    ``fault_plan`` schedules kv-plane injections by batcher tick (the
    `FaultSpec.step` coordinate); ``guard`` turns the per-tick slot
    scan + admission check on (defaults on exactly when a plan is
    given — the scan costs a host gather of the pool per tick)."""

    def __init__(self, params, cfg, *, num_slots: int, cache_len: int,
                 kv_codec: Optional[KVCodec] = None,
                 hop_codec: Optional[DeltaHopCodec] = None,
                 num_stages: int = 1, block_k: int = 512,
                 eos_id: Optional[int] = None, dtype=jnp.bfloat16,
                 fault_plan: Optional[F.FaultPlan] = None,
                 guard: Optional[bool] = None):
        self.params, self.cfg = params, cfg
        self.num_slots, self.cache_len = num_slots, cache_len
        self.kv_codec = kv_codec if (kv_codec and kv_codec.bits) else None
        self.hop_codec = hop_codec
        self.num_stages = num_stages
        self.block_k, self.eos_id, self.dtype = block_k, eos_id, dtype
        self.fault_plan = fault_plan or F.FaultPlan()
        self.guard = bool(self.fault_plan) if guard is None else guard
        self._tick = 0
        self._fired: set = set()
        self.requests: list[ServeRequest] = []
        self._slots: list[Optional[ServeRequest]] = [None] * num_slots
        self._next_tok = np.zeros((num_slots,), np.int32)
        self.caches = self._init_pool()
        self._decode = self._build_decode()
        self._prefill_cache = {}

    # -- pool construction --------------------------------------------------

    def _row_caches(self, batch: int):
        caches = Mo.init_caches(self.cfg, batch, self.cache_len,
                                self.dtype)
        if self.kv_codec is not None:
            caches = quantize_caches(self.cfg, caches, self.kv_codec)
        if self.hop_codec is not None and self.num_stages > 1:
            caches["hop_m"] = self.hop_codec.init_state(
                self.num_stages - 1, batch, self.cfg.d_model)["m"]
        return caches

    def _init_pool(self):
        pool = self._row_caches(self.num_slots)
        # per-slot positions replace the scalar pos of a uniform batch
        pool["pos"] = jnp.zeros((self.num_slots,), jnp.int32)
        return pool

    # -- compiled steps -----------------------------------------------------

    def _build_decode(self):
        cfg, block_k = self.cfg, self.block_k
        kv_codec, num_stages = self.kv_codec, self.num_stages
        bfn = (self.hop_codec.boundary_fn(prefill=False)
               if self.hop_codec is not None and num_stages > 1 else None)

        def row_step(params, row, token):
            # re-expand the batch dim vmap stripped: leaf (L, S, ...)
            # -> (L, 1, S, ...), pos stays the row's own scalar
            caches = {k: (v if k == "pos" else v[:, None])
                      for k, v in row.items()}
            logits, nc = Mo.forward_with_caches(
                params, cfg, token[None, None], caches, block_k=block_k,
                logits_last_only=True, num_stages=num_stages,
                boundary_fn=bfn, kv_codec=kv_codec)
            nc = {k: (v if k == "pos" else v[:, 0])
                  for k, v in nc.items()}
            return jnp.argmax(logits[0, -1]).astype(jnp.int32), nc

        axes = {k: (0 if k == "pos" else 1) for k in self.caches}
        return jax.jit(jax.vmap(row_step, in_axes=(None, axes, 0),
                                out_axes=(0, axes)))

    def _prefill(self, prompt: np.ndarray):
        """B=1 exact-length prefill; compiled per unique prompt length."""
        fn = self._prefill_cache.get(len(prompt))
        if fn is None:
            cfg, block_k = self.cfg, self.block_k
            kv_codec, num_stages = self.kv_codec, self.num_stages
            bfn = (self.hop_codec.boundary_fn(prefill=True)
                   if self.hop_codec is not None and num_stages > 1
                   else None)

            def fill(params, caches, tokens):
                logits, nc = Mo.forward_with_caches(
                    params, cfg, tokens, caches, block_k=block_k,
                    logits_last_only=True, num_stages=num_stages,
                    boundary_fn=bfn, kv_codec=kv_codec)
                return jnp.argmax(logits[0, -1]).astype(jnp.int32), nc

            fn = self._prefill_cache[len(prompt)] = jax.jit(fill)
        caches = self._row_caches(1)
        return fn(self.params, caches, jnp.asarray(prompt)[None, :])

    # -- slot bookkeeping ---------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 16) -> ServeRequest:
        req = ServeRequest(list(prompt), max_new_tokens)
        self.requests.append(req)
        return req

    def _write_slot(self, i: int, row_caches):
        for name, leaf in row_caches.items():
            if name == "pos":
                self.caches["pos"] = self.caches["pos"].at[i].set(leaf)
            else:
                self.caches[name] = \
                    self.caches[name].at[:, i].set(leaf[:, 0])

    def _row_bad(self, row) -> bool:
        """Admission guard: is this prefill row's float payload
        corrupt (non-finite or above the guard bound)?"""
        return any(F._arr_detail(leaf) is not None
                   for leaf in row.values())

    def _admit(self):
        pending = [r for r in self.requests if r.state == PENDING]
        for i, slot in enumerate(self._slots):
            if slot is not None or not pending:
                continue
            req = pending.pop(0)
            tok, row = self._prefill(np.asarray(req.prompt, np.int32))
            if self.guard and self._row_bad(row):
                # poisoned before it ever touched the pool: reject at
                # admission, never occupy a slot
                req.state = DONE
                req.error = (f"wire fault detected: plane=kv "
                             f"wire='paged' tick={self._tick}: "
                             f"corrupt prefill payload")
                continue
            self._write_slot(i, row)
            req.state, req.slot = ACTIVE, i
            self._slots[i] = req
            self._emit(req, int(tok))
            self._next_tok[i] = int(tok)

    def _emit(self, req: ServeRequest, tok: int):
        req.tokens.append(tok)
        done = (self.eos_id is not None and tok == self.eos_id) \
            or len(req.tokens) >= req.max_new_tokens
        if done:
            req.state = DONE
            self._slots[req.slot] = None
            req.slot = -1

    def _evict_faulted(self, req: ServeRequest, detail: str):
        """Slot-level isolation: the poisoned request leaves the pool
        as DONE(error); its row is dead until the next admission
        overwrites every leaf (`_write_slot` writes the full row)."""
        req.error = (f"wire fault detected: plane=kv wire='paged' "
                     f"tick={self._tick}: {detail}")
        req.state = DONE
        self._slots[req.slot] = None
        req.slot = -1

    def _inject_faults(self):
        """Fire due kv-plane faults into the lowest-index active slot
        (each spec fires once, at the first due tick with a victim)."""
        for spec in self.fault_plan.faults:
            if spec.plane != "kv" or spec in self._fired \
                    or self._tick < spec.step:
                continue
            victims = [i for i, r in enumerate(self._slots)
                       if r is not None]
            if not victims:
                continue       # no active slot yet; retry next tick
            v = victims[0]
            self._fired.add(spec)
            for name in self.caches:
                leaf = self.caches[name]
                if name == "pos" or not F._is_float(leaf):
                    continue
                self.caches[name] = leaf.at[:, v].set(
                    F.corrupt_array(leaf[:, v], spec.kind))

    # -- drive --------------------------------------------------------------

    def step(self):
        """One batched decode tick over every slot (idle rows advance on
        garbage and are ignored — the price of a static shape).  With
        the guard on, the pool is scanned after the decode and any
        ACTIVE slot carrying corrupt payload is evicted BEFORE its
        (garbage) token is emitted — `jax.vmap` row independence keeps
        every surviving slot's stream bit-identical."""
        self._inject_faults()
        toks, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self._next_tok))
        toks = np.asarray(toks)
        flags = F.slot_flags(self.caches) if self.guard \
            else np.zeros(self.num_slots, bool)
        for i, req in enumerate(self._slots):
            self._next_tok[i] = int(toks[i])
            if req is None:
                continue
            if flags[i]:
                self._evict_faulted(req, "corrupt cache payload")
            else:
                self._emit(req, int(toks[i]))
        self._tick += 1

    def run(self, max_ticks: int = 10_000) -> list:
        """Admit + decode until every submitted request is DONE; returns
        the requests in submission order."""
        for _ in range(max_ticks):
            self._admit()
            if all(r.state == DONE for r in self.requests):
                break
            self.step()
        return self.requests
