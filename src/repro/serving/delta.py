"""Delta-encoded pipeline hops for autoregressive decode.

The paper's core trick — quantize the CHANGE in an activation against a
reference buffer instead of the value (AC-SGD / AQ-SGD) — applied to
the serving plane: during decode, consecutive tokens' hidden states at
a pipeline boundary drift slowly, so the inter-stage hop ships
``Q(h_t - m)`` against a per-boundary reference ``m`` and both sides
advance ``m += dequant(codes)`` in lockstep, exactly Algorithm 2's
sender/receiver discipline with the per-sample message buffer replaced
by a per-(boundary, batch-row) reference.

Modes mirror the training activation plane (`CommConfig.mode`):

* ``aqsgd``   — delta codec: `core.boundary.encode_delta` on the send
  side, `decode_accumulate` on the receive side (bit-identical m / h'
  by the boundary-parity contract, so the simulated single-process hop
  below is bit-faithful to a real two-machine ppermute crossing);
* ``directq`` — quantize the value itself every hop (`roundtrip`);
* ``fp32``    — pass-through (the uncompressed baseline).

Warmup: the PREFILL pass always crosses uncompressed and initializes
``m`` from the last prompt position's hidden state — the serving
analogue of the paper's uncompressed first epoch, giving the delta
codec a reference that is already one token-step close.

The wire claim is the registered fw-activation ``ppermute`` wire's
``wire_bytes`` model over the ``(B, 1, d)`` decode hop — pinned
against compiled ppermute collective bytes in tests/test_hlo_cost.py.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.comm import wires as W
from repro.core import boundary as B


@dataclass(frozen=True)
class DeltaHopCodec:
    """Decode-hop codec for one pipeline mesh: mode + fw-plane knobs.

    ``num_boundaries = num_stages - 1`` reference buffers of shape
    ``(B, 1, d)`` — one per inter-stage hop, advanced once per decoded
    token.  Deterministic rounding by default: both ends of a real wire
    must reconstruct identical references without sharing PRNG keys."""
    mode: str = "aqsgd"                 # aqsgd | directq | fp32
    bits: int = 4
    stochastic: bool = False
    backend: str = "auto"

    def __post_init__(self):
        assert self.mode in ("aqsgd", "directq", "fp32"), self.mode

    @classmethod
    def from_comm(cls, comm) -> "DeltaHopCodec":
        """Bind `repro.comm.CommConfig`'s mode + fw plane.  Rounding is
        forced deterministic regardless of ``fw.stochastic``: the train
        plane dithers for unbiased gradients, but a decode hop's two
        ends must advance bit-identical references keylessly."""
        return cls(mode=comm.mode, bits=comm.fw.bits or 4,
                   stochastic=False, backend=comm.fw.backend)

    def init_state(self, num_boundaries: int, batch: int, d: int) -> dict:
        """Zero reference buffers (filled by the prefill crossing)."""
        return {"m": jnp.zeros((max(num_boundaries, 1), batch, 1, d),
                               jnp.float32)}

    def prefill_boundary(self, state, h, idx):
        """Prefill crossing: uncompressed pass-through; the reference
        becomes the LAST prompt position's hidden state (the value the
        first decode-step delta is measured against)."""
        if self.mode == "fp32":
            return state, h
        m = state["m"].at[idx].set(
            h[:, -1:, :].astype(jnp.float32))
        return {"m": m}, h

    def decode_boundary(self, state, h, idx, *, key=None):
        """One decode-token crossing of boundary ``idx``; h (B, 1, d).

        aqsgd: the receiver's ``decode_accumulate`` output IS the new
        reference (bit-identical to the sender's ``m_new`` by the
        parity contract), so one state update serves both ends."""
        if self.mode == "fp32":
            return state, h
        if self.mode == "directq":
            return state, B.roundtrip(
                h, bits=self.bits, stochastic=self.stochastic, key=key,
                backend=self.backend).astype(h.dtype)
        m = state["m"][idx]
        packed, scale, m_new = B.encode_delta(
            h, m, bits=self.bits, stochastic=self.stochastic, key=key,
            backend=self.backend)
        h2 = B.decode_accumulate(packed, scale, m, bits=self.bits,
                                 backend=self.backend)
        return ({"m": state["m"].at[idx].set(m_new)},
                h2.astype(h.dtype))

    def boundary_fn(self, *, prefill: bool, key=None):
        """The ``boundary_fn(state, h, idx) -> (state, h)`` hook
        `models.model.forward_with_caches` runs between stage groups."""
        if prefill:
            return self.prefill_boundary

        def fn(state, h, idx):
            k = jax.random.fold_in(key, idx) if key is not None else None
            return self.decode_boundary(state, h, idx, key=k)
        return fn

    def hop_bytes(self, batch: int, d: int) -> int:
        """Modeled network bytes for ONE decode-token hop across one
        boundary — the registered fw-plane ``ppermute`` wire's uniform
        byte model (raw f32 for the fp32 pass-through)."""
        spec = W.get_wire("ppermute", plane="fw-activation")
        if self.mode == "fp32":
            return batch * d * 4
        return spec.wire_bytes((batch, 1, d), self.bits, 1)
