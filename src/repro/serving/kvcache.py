"""Quantized KV cache: quantize-on-append, dequantize-on-attend.

The serving analogue of the z-buffer plane: the decode-time KV cache is
stored as packed b-bit codes plus one f32 scale per quantization group,
cutting HBM residency ~``32/bits``x for long contexts.  The codec knobs
live on the ``kv`` plane of `repro.comm.CommConfig` (``kv.bits``,
``kv.group_d``, ``kv.stochastic``) and the byte claim is the registered
``paged`` wire's ``wire_bytes`` model (`repro.comm.wires`), pinned
against the compiled append op's output buffers by tests/test_hlo_cost.py.

Layout.  A raw layer cache row is ``(B, S, Hk, head_dim)``.  The codec
reshapes ``head_dim`` into ``(G, group)`` scale groups (``group =
kv.group_d or head_dim`` — the default is one scale per head row) and
stores

* ``codes``  u8  ``(L, B, S, Hk, G, packed_width(group, bits))``
* ``scale``  f32 ``(L, B, S, Hk, G)``

Append discipline: each `forward_with_caches` step dequantizes the
whole layer cache (one fused pass per layer inside the scan), lets
attention scatter the step's FRESH raw rows in, attends, then encodes
ONLY those fresh rows back into the code store.  Old tokens are encoded
exactly once — re-quantization error never accumulates — which is what
makes the greedy-equivalence gate (fp32 vs 8-bit cache, identical
argmax tokens; tests/test_serving.py) a fair fight.

All quantization goes through the backend-selectable boundary ops
(`core.boundary.encode`/`decode`), so the ``reference|pallas|auto``
bit-parity contract of the training wires applies verbatim.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import boundary as B
from repro.core import quantization as Q


@dataclass(frozen=True)
class KVCodec:
    """The kv plane's codec: bits/group/stochastic/backend bound once.

    ``bits=0`` disables quantization (raw dtype cache, the seed
    behaviour).  ``group_d=0`` means one scale group per head row
    (group = head_dim).  Rounding is deterministic by default — decode
    must be reproducible across replays of the same request."""
    bits: int = 0
    group_d: int = 0
    stochastic: bool = False
    backend: str = "auto"

    @classmethod
    def from_comm(cls, comm) -> "KVCodec":
        """Bind the ``kv`` plane of a `repro.comm.CommConfig`."""
        pc = comm.kv
        return cls(bits=pc.bits, group_d=pc.group_d,
                   stochastic=pc.stochastic, backend=pc.backend)

    def group(self, head_dim: int) -> int:
        """Scale-group width along head_dim."""
        g = self.group_d or head_dim
        assert head_dim % g == 0, (head_dim, g)
        if self.bits in B.PACKABLE_BITS:
            # byte-aligned packing must round-trip without padding so
            # the decode side can recover g from the packed width
            assert g % Q.codes_per_byte(self.bits) == 0, (g, self.bits)
        return g

    def grouped_shape(self, shape) -> tuple:
        """(..., head_dim) value shape -> (..., G, group) grouped shape
        (what the registered ``paged`` wire's byte model consumes)."""
        *lead, hd = shape
        g = self.group(hd)
        return (*lead, hd // g, g)

    def stored_bytes(self, shape) -> int:
        """Modeled HBM bytes for one append of value shape
        ``(..., head_dim)`` — delegates to the grouped `Q.wire_bytes`
        form the registry pins (raw f32 when bits=0)."""
        if not self.bits:
            return int(np.prod(shape)) * 4
        return Q.wire_bytes(self.grouped_shape(shape), self.bits)

    # -- cache structure ---------------------------------------------------

    def empty(self, shape, dtype=jnp.bfloat16):
        """Zero cache store for a raw value shape ``(..., head_dim)``:
        ``{"codes", "scale"}`` when quantized, a raw zeros array when
        bits=0.  Zero codes + zero scales decode to exact zeros, so an
        empty quantized cache attends identically to an empty raw one."""
        if not self.bits:
            return jnp.zeros(shape, dtype)
        *lead, hd = shape
        g = self.group(hd)
        pw = Q.packed_width(g, self.bits)
        return {"codes": jnp.zeros((*lead, hd // g, pw), jnp.uint8),
                "scale": jnp.zeros((*lead, hd // g), jnp.float32)}

    def encode(self, values, *, key=None):
        """Quantize fresh rows ``(..., head_dim)`` -> (codes, scale)
        in the grouped store layout."""
        g = self.group(values.shape[-1])
        grouped = values.reshape(*values.shape[:-1], -1, g)
        packed, scale = B.encode(grouped, bits=self.bits,
                                 stochastic=self.stochastic, key=key,
                                 backend=self.backend)
        return packed, scale[..., 0]

    def decode(self, codes, scale, dtype=jnp.bfloat16):
        """Whole-store dequantize: (codes (..., G, pw), scale (..., G))
        -> values (..., head_dim) in the attend dtype."""
        g = self._group_of(codes.shape[-1])
        vals = B.decode(codes, scale[..., None], bits=self.bits,
                        d=g, dtype=dtype, backend=self.backend)
        return vals.reshape(*codes.shape[:-2], -1)

    def _group_of(self, pw: int) -> int:
        """Recover the group width from a code store's packed width
        (exact: `group` requires byte-aligned packing, so pw carries no
        padding)."""
        if self.group_d:
            return self.group_d
        if self.bits in B.PACKABLE_BITS:
            return pw * Q.codes_per_byte(self.bits)
        return pw                  # non-byte-aligned widths ship raw u8

    def append(self, store, values, pos, *, key=None):
        """Encode ``values (B, s, Hk, head_dim)`` and write them at
        sequence position ``pos`` (traced int32) of a layer store —
        the quantize-on-append op the HLO regression compiles."""
        codes, scale = self.encode(values, key=key)
        return {
            "codes": jax.lax.dynamic_update_slice_in_dim(
                store["codes"], codes, pos, axis=1),
            "scale": jax.lax.dynamic_update_slice_in_dim(
                store["scale"], scale, pos, axis=1),
        }


def quantize_caches(cfg, caches: dict, codec: KVCodec) -> dict:
    """Convert a raw `models.model.init_caches` dict into the quantized
    layout: the scanned ``k``/``v`` stores become ``{k,v}_codes`` +
    ``{k,v}_scale``.  Prefix-layer caches (``pk``/``pv``, DeepSeek's
    leading dense layers), audio cross-attention caches, and SSM state
    stay raw — they are O(first_dense_layers) or position-independent
    and outside the long-context growth term this plane compresses."""
    if not codec.bits:
        return caches
    if cfg.family == "hybrid":
        raise NotImplementedError(
            "kv.bits > 0 is not wired for the hybrid family's shared "
            "attention block yet — set kv.bits=0 for zamba2")
    out = dict(caches)
    for name in ("k", "v"):
        if name not in out:
            return caches                      # ssm: nothing to quantize
        arr = out.pop(name)
        store = codec.empty(arr.shape)
        out[name + "_codes"] = store["codes"]
        out[name + "_scale"] = store["scale"]
    return out


def init_quant_caches(cfg, batch_size: int, cache_len: int,
                      codec: KVCodec, dtype=jnp.bfloat16) -> dict:
    """`models.model.init_caches` followed by `quantize_caches`."""
    from repro.models import model as Mo
    return quantize_caches(
        cfg, Mo.init_caches(cfg, batch_size, cache_len, dtype), codec)
