"""Compressed serving plane: pjit decode + the paper's codecs at
inference time.

* `decode` — sharded prefill / single-token decode steps (pjit);
* `delta` — AC-SGD-style delta codec for the inter-stage decode hop;
* `kvcache` — quantized KV cache (the ``kv`` plane of CommConfig);
* `batcher` — minimal continuous batching over paged cache slots.
"""
from repro.serving.batcher import ContinuousBatcher, ServeRequest
from repro.serving.delta import DeltaHopCodec
from repro.serving.kvcache import KVCodec, init_quant_caches, \
    quantize_caches

__all__ = [
    "ContinuousBatcher", "ServeRequest", "DeltaHopCodec", "KVCodec",
    "init_quant_caches", "quantize_caches",
]
