"""pjit serving: sharded prefill / single-token decode.

Decode shapes (decode_32k, long_500k) lower ``serve_step`` — one new
token against a KV/SSM cache of ``seq_len`` — under 2-D GSPMD sharding:

* weights: last dim over ``model``, second-to-last over ``data`` where
  divisible (fully-sharded weights so ≥70 GB models fit 16 GB/chip);
* caches: batch over the data axes when divisible, else the cache
  sequence dim; sequence or heads over ``model``;
* ``pod`` folds into data parallelism.

GSPMD propagates interior shardings and inserts the collectives; the
dry-run reads them back out of the lowered HLO for §Roofline.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import data_axes
from repro.models import model as Mo


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def _axis_sizes(mesh, axes):
    return int(np.prod([mesh.shape[a] for a in axes]))


def leaf_spec(mesh, shape, *, skip_leading: int = 0) -> P:
    """Generic 2-D weight rule: last dim -> model, previous dim -> data."""
    daxes = data_axes(mesh)
    dsize = _axis_sizes(mesh, daxes)
    msize = mesh.shape["model"]
    spec: list = [None] * len(shape)
    dims = [i for i in range(len(shape)) if i >= skip_leading]
    if dims and shape[dims[-1]] % msize == 0:
        spec[dims[-1]] = "model"
    if len(dims) > 1 and shape[dims[-2]] % dsize == 0:
        spec[dims[-2]] = daxes if len(daxes) > 1 else daxes[0]
    return P(*spec)


# params subtrees whose leaves carry a leading layer-stack dim that must
# never be sharded (it is scanned over, not a tensor dim)
STACKED_KEYS = ("layers", "enc_layers")


def param_shardings(cfg: ModelConfig, mesh, params_shape) -> Any:
    """Shardings for a params pytree (ShapeDtypeStructs or arrays).

    Stackedness is read off the tree STRUCTURE (top-level key in
    `STACKED_KEYS`), not guessed from rank: the old ``ndim >= 3``
    heuristic data-sharded dim 0 of stacked 2-D leaves — e.g. a
    whisper/pixtral per-layer norm stack ``(L, d)`` got its LAYER dim
    split over data whenever ``L % dsize == 0``, which is wrong for the
    scan carrying it."""
    def rule(path, leaf):
        top = path[0].key if hasattr(path[0], "key") else str(path[0])
        skip = 1 if top in STACKED_KEYS else 0
        return NamedSharding(mesh, leaf_spec(mesh, leaf.shape,
                                             skip_leading=skip))
    return jax.tree_util.tree_map_with_path(rule, params_shape)


def cache_shardings(cfg: ModelConfig, mesh, cache_shape) -> Any:
    """Name-keyed cache rules: batch over data axes, sequence/heads
    over model; quantized ``{k,v}_codes``/``{k,v}_scale`` stores and
    the delta-hop ``hop_m`` buffers follow the raw leaves' layout."""
    daxes = data_axes(mesh)
    dsize = _axis_sizes(mesh, daxes)
    msize = mesh.shape["model"]
    d = daxes if len(daxes) > 1 else daxes[0]

    def rule(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "pos":
            return NamedSharding(mesh, P())
        shape = leaf.shape
        if name in ("k", "v", "pk", "pv", "xk", "xv",
                    "k_codes", "v_codes", "k_scale", "v_scale"):
            # raw (L, B, S, Hk, hd); quantized codes (L, B, S, Hk, G, pw)
            # and scales (L, B, S, Hk, G) share the batch/seq layout
            spec = [None] * len(shape)
            if shape[1] % dsize == 0:
                spec[1] = d
                spec[2] = "model" if shape[2] % msize == 0 else None
            elif shape[2] % (dsize * msize) == 0:
                spec[2] = (*daxes, "model")
            elif shape[2] % msize == 0:
                spec[2] = "model"
            return NamedSharding(mesh, P(*spec))
        if name == "hop_m":
            # delta-hop references (nb, B, 1, d): batch over data
            spec = [None] * 4
            if shape[1] % dsize == 0:
                spec[1] = d
            return NamedSharding(mesh, P(*spec))
        if name == "ssm":
            spec = [None] * 5
            if shape[1] % dsize == 0:
                spec[1] = d
            if shape[2] % msize == 0:
                spec[2] = "model"
            return NamedSharding(mesh, P(*spec))
        if name == "conv":
            spec = [None] * 4
            if shape[1] % dsize == 0:
                spec[1] = d
            if shape[3] % msize == 0:
                spec[3] = "model"
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def batch_sharding(mesh, shape) -> NamedSharding:
    """Tokens / patches / frames: batch over data axes when divisible."""
    daxes = data_axes(mesh)
    dsize = _axis_sizes(mesh, daxes)
    spec = [None] * len(shape)
    if shape[0] % dsize == 0:
        spec[0] = daxes if len(daxes) > 1 else daxes[0]
    return NamedSharding(mesh, P(*spec))


# ---------------------------------------------------------------------------
# serve step
# ---------------------------------------------------------------------------

def serve_step(params, caches, tokens, *, cfg: ModelConfig,
               block_k: int = 512):
    """One decode step: (B, 1) token -> (B, 1, V) logits + new caches."""
    logits, new_caches = Mo.forward_with_caches(
        params, cfg, tokens, caches, block_k=block_k)
    return logits, new_caches


def prefill_step(params, caches, tokens, *, cfg: ModelConfig,
                 patches=None, frames=None, block_k: int = 512):
    """Prompt pass: (B, S) tokens -> (B, S, V) logits + filled caches."""
    logits, new_caches = Mo.forward_with_caches(
        params, cfg, tokens, caches, patches=patches, frames=frames,
        block_k=block_k)
    return logits, new_caches


def logits_sharding(cfg: ModelConfig, mesh) -> NamedSharding:
    """Vocab-sharded logits when the model axis divides the vocab."""
    spec = P(None, None, "model") \
        if cfg.vocab_size % mesh.shape["model"] == 0 else P()
    return NamedSharding(mesh, spec)


def jit_serve_step(cfg: ModelConfig, mesh, params_shape, cache_shape,
                   token_shape, donate: bool = True):
    """jit `serve_step` with the full in/out sharding rule set (caches
    donated by default — decode rewrites them in place)."""
    ps = param_shardings(cfg, mesh, params_shape)
    cs = cache_shardings(cfg, mesh, cache_shape)
    ts = batch_sharding(mesh, token_shape.shape)
    logits_s = logits_sharding(cfg, mesh)
    fn = functools.partial(serve_step, cfg=cfg)
    return jax.jit(fn, in_shardings=(ps, cs, ts),
                   out_shardings=(logits_s, cs),
                   donate_argnums=(1,) if donate else ())
