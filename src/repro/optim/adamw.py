"""AdamW + LR schedules, pure JAX (no optax in this container).

The paper fine-tunes with AdamW, linear warmup then linear decay
(Appendix C); we reproduce exactly that schedule shape.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 5e-6
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "linear"        # linear | constant
    state_bits: int = 0             # 0 = fp32 moments; 8 = int8-quantized
                                    # moments w/ per-row scales (8-bit Adam
                                    # — in the spirit of the paper, state
                                    # is quantized, not just wires)


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    decay = jnp.clip(
        (cfg.total_steps - step) /
        jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * decay


def _q_enc(x, bits: int):
    """Symmetric per-row quantization of a moment tensor.  Operates on
    the native shape — reshapes across sharded dims would make GSPMD
    replicate the fp32 moments."""
    from repro.core import quantization as Q
    codes, scale = Q.quantize(x, bits, stochastic=False)
    return {"codes": codes, "scale": scale}


def _q_dec(enc, shape, bits: int):
    from repro.core import quantization as Q
    return Q.dequantize(enc["codes"], enc["scale"], bits)


def init_opt_state(params, state_bits: int = 0) -> dict:
    if state_bits:
        enc = lambda p: _q_enc(jnp.zeros_like(p, jnp.float32), state_bits)
        return {"mu": jax.tree.map(enc, params),
                "nu": jax.tree.map(enc, params),
                "step": jnp.zeros((), jnp.int32)}
    zeros = lambda p: jnp.zeros_like(p, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def init_bucket_opt_state(n_ranks: int, seg: int, group_d: int) -> dict:
    """ZeRO-sharded moments for the ``ring-sharded`` DP wire: one
    (seg, group_d) segment of the flattened gradient bucket per DP
    rank, stacked (n_ranks, seg, group_d) and sharded one segment per
    segment owner (`training/pipeline.py` places them P(data-axes)).

    Replaces the per-leaf `init_opt_state` tree when the optimizer runs
    in bucket space — each rank only ever reads and writes the moments
    of the segment it owns."""
    zeros = jnp.zeros((n_ranks, seg, group_d), jnp.float32)
    return {"mu": zeros, "nu": jnp.zeros_like(zeros),
            "step": jnp.zeros((), jnp.int32)}


def apply_bucket_updates(cfg: AdamWConfig, pbucket, gbucket,
                         state) -> tuple[Any, dict]:
    """AdamW on the flattened (n, seg, group_d) parameter bucket —
    the segment-owner update of the ZeRO-sharded DP wire.

    pbucket: f32 parameter segments (n, seg, group_d), rank i's owned
    segment at index i; gbucket: the segment means
    `ring_ef_reduce_scatter_bucket` left on each owner; state: from
    `init_bucket_opt_state`.  Returns (new pbucket, new state).

    The update math is ELEMENTWISE-IDENTICAL to `apply_updates` on f32
    leaves (same ops, same association), so updating owned segments in
    bucket space and all-gathering the parameter bucket reproduces the
    replicated path bit-for-bit — the loss-parity anchor
    `tests/workers/pipeline_worker.py::check_dp_wire_parity` pins.
    Quantized moments (`state_bits`) are a per-leaf feature and are not
    supported in bucket space."""
    assert not cfg.state_bits, \
        "state_bits (8-bit Adam) is per-leaf; unsupported with the " \
        "bucket-space sharded optimizer (dp_wire='ring-sharded')"
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    g = gbucket.astype(jnp.float32)
    mu = b1 * state["mu"] + (1 - b1) * g
    nu = b2 * state["nu"] + (1 - b2) * jnp.square(g)
    d = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
    d = d + cfg.weight_decay * pbucket.astype(jnp.float32)
    new_p = pbucket.astype(jnp.float32) - lr * d
    return new_p, {"mu": mu, "nu": nu, "step": step}


def apply_updates(cfg: AdamWConfig, params, grads, state) -> tuple[Any, dict]:
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    qb = cfg.state_bits

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        if qb:
            mu = _q_dec(mu, p.shape, qb)
            nu = jnp.square(_q_dec(nu, p.shape, qb))  # nu stored as sqrt
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        d = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        d = d + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * d).astype(p.dtype)
        if qb:
            # sqrt-compand nu: preserves resolution of small 2nd moments
            return new_p, _q_enc(mu, qb), _q_enc(jnp.sqrt(nu), qb)
        return new_p, mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    new = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    params = tdef.unflatten([t[0] for t in new])
    mu = tdef.unflatten([t[1] for t in new])
    nu = tdef.unflatten([t[2] for t in new])
    return params, {"mu": mu, "nu": nu, "step": step}
