"""Versioned full-state checkpointing (manifest + checksums).

Public surface re-exported from `repro.checkpoint.checkpoint`: the
legacy single-file `save`/`restore` pair (params-only export) and the
manifest-based `save_state`/`restore_state` subsystem with
`latest_step`/`checkpoint_steps` discovery and `clean_orphans`
crash-residue cleanup.  See the submodule docstring for the on-disk
layout and the crash-safety / fail-closed verification protocol.
"""
from repro.checkpoint.checkpoint import (  # noqa: F401
    ARRAYS_NAME,
    MANIFEST_NAME,
    CheckpointError,
    checkpoint_steps,
    clean_orphans,
    flatten_tree,
    latest_step,
    resolve_checkpoint,
    restore,
    restore_state,
    save,
    save_state,
    tree_fingerprint,
)

__all__ = [
    "ARRAYS_NAME", "MANIFEST_NAME", "CheckpointError",
    "checkpoint_steps", "clean_orphans", "flatten_tree", "latest_step",
    "resolve_checkpoint", "restore", "restore_state", "save",
    "save_state", "tree_fingerprint",
]
