"""Versioned, manifest-based full-state checkpointing.

A checkpoint is a directory ``<dir>/step_00000123/`` holding exactly
two files:

* ``arrays.npz``    — every leaf of the state pytree, path-encoded
  keys (``params/layers/wq`` …), ml_dtypes leaves (bf16/f8) stored as
  f32 and re-cast on restore (exact: f32 is a superset of bf16);
* ``manifest.json`` — a CRC-protected JSON record of the format
  version, the step, the run's ``CommConfig.to_json()`` payload, a
  fingerprint of the state STRUCTURE (sorted (path, shape, dtype)
  triples), per-array CRC32 checksums, the whole-file SHA-256 of
  ``arrays.npz``, and free-form ``extra`` metadata (PRNG key, data
  position, last loss).

Write protocol (crash-safe, satellite of ISSUE 8): stage into a
UNIQUE ``.tmp-<pid>-<uuid>/`` directory inside ``<dir>``, fsync both
files, then ``os.rename`` the staged directory into place and fsync
the parent.  A kill at any point leaves either the previous
checkpoint set intact or an orphaned ``.tmp-*`` directory that
`clean_orphans` removes on startup — a stale tmp can never be renamed
over a good checkpoint (the old single-name ``path + ".tmp"`` scheme
could).  Rotation (``keep`` last k) renames the victim to a tmp name
before deleting, so a crash mid-rotation also degrades to an orphan.

Read protocol (fail closed): the manifest's own CRC, the npz SHA-256,
and every per-array CRC32 are verified BEFORE any value is returned;
a single flipped byte in either file raises :class:`CheckpointError`
naming the corrupt artifact.  Structure mismatches (a checkpoint from
a different config) raise a loud diff of missing / unexpected /
mismatched paths plus both fingerprints — never a bare ``KeyError``
or shape assert.  When the caller passes its live ``CommConfig``, a
differing stored comm config is reported key-by-key.

The legacy single-file API (`save`/`restore` on one ``.npz``) is kept
for params-only export (``launch.train --checkpoint``, benchmarks)
with the same hardened tmp protocol and loud restore errors.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import uuid
import zlib
from typing import Any, Optional

import jax
import numpy as np

FORMAT_VERSION = 1
ARRAYS_NAME = "arrays.npz"
MANIFEST_NAME = "manifest.json"
STEP_PREFIX = "step_"
TMP_PREFIX = ".tmp-"


class CheckpointError(RuntimeError):
    """A checkpoint could not be written, found, verified, or mapped
    onto the requested state structure.  Always actionable: the
    message names the offending file/paths instead of surfacing a
    bare ``KeyError`` / shape assert from the guts of the loader."""


# ---------------------------------------------------------------------------
# pytree <-> flat dict of numpy arrays
# ---------------------------------------------------------------------------

def _leaf_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def flatten_tree(tree: Any) -> dict:
    """Flatten a pytree into ``{path-key: np.ndarray}`` (the npz
    payload).  ml_dtypes leaves (bf16/f8 — numpy kind outside
    ``biufc``) are stored as f32; `restore` re-casts them exactly."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":
            arr = arr.astype(np.float32)
        flat[_leaf_key(path)] = arr
    return flat


def _struct_items(tree: Any) -> list:
    """Sorted (key, shape, logical-dtype) triples of a pytree whose
    leaves are arrays OR ShapeDtypeStructs (eval_shape output)."""
    items = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        items.append((_leaf_key(path), tuple(int(s) for s in leaf.shape),
                      str(np.dtype(leaf.dtype))))
    return sorted(items)


def tree_fingerprint(tree: Any) -> str:
    """SHA-256 over the sorted (path, shape, dtype) triples of a
    pytree — the state-STRUCTURE identity the manifest records.  Two
    trees fingerprint equal iff `restore_state` can map one's arrays
    onto the other bit-exactly."""
    blob = json.dumps(_struct_items(tree)).encode()
    return hashlib.sha256(blob).hexdigest()


def _restore_flat(flat: dict, like: Any, *, where: str,
                  stored_fp: Optional[str] = None) -> Any:
    """Map a flat ``{key: array}`` dict onto the structure of `like`.

    Any missing / unexpected / shape-mismatched path fails LOUDLY
    with the full diff and (when known) both structure fingerprints —
    the satellite replacing the old bare KeyError/AssertionError."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    want = {_leaf_key(p): leaf for p, leaf in leaves}
    missing = sorted(set(want) - set(flat))
    unexpected = sorted(set(flat) - set(want))
    mismatched = sorted(
        (k, flat[k].shape, want[k].shape) for k in set(want) & set(flat)
        if tuple(flat[k].shape) != tuple(want[k].shape))
    if missing or unexpected or mismatched:
        lines = [f"checkpoint {where} does not match the requested "
                 f"state structure:"]
        lines += [f"  missing from checkpoint: {k} "
                  f"(want {want[k].shape} {np.dtype(want[k].dtype)})"
                  for k in missing]
        lines += [f"  unexpected in checkpoint: {k} {flat[k].shape}"
                  for k in unexpected]
        lines += [f"  shape mismatch: {k} stored {s} != wanted {w}"
                  for k, s, w in mismatched]
        if stored_fp is not None:
            lines.append(f"  manifest fingerprint {stored_fp} != "
                         f"state-struct fingerprint "
                         f"{tree_fingerprint(like)} — the checkpoint "
                         f"was written by a different model/comm/"
                         f"optimizer configuration")
        raise CheckpointError("\n".join(lines))
    out = [flat[_leaf_key(p)].astype(np.dtype(leaf.dtype))
           for p, leaf in leaves]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# durable file primitives
# ---------------------------------------------------------------------------

def _fsync_path(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _tmp_name() -> str:
    return f"{TMP_PREFIX}{os.getpid()}-{uuid.uuid4().hex[:12]}"


def clean_orphans(directory: str) -> list:
    """Remove crash residue: ``.tmp-*`` staging entries (and legacy
    ``*.tmp*.npz`` single-file temps) left in ``directory`` by a
    killed writer.  Called on trainer startup; returns the removed
    names.  Committed checkpoints are never touched."""
    removed = []
    if not os.path.isdir(directory):
        return removed
    for name in sorted(os.listdir(directory)):
        p = os.path.join(directory, name)
        if name.startswith(TMP_PREFIX) or (".tmp" in name
                                           and name.endswith(".npz")):
            shutil.rmtree(p) if os.path.isdir(p) else os.remove(p)
            removed.append(name)
    return removed


# ---------------------------------------------------------------------------
# legacy single-file API (params-only export) — hardened
# ---------------------------------------------------------------------------

def save(path: str, tree: Any) -> None:
    """Write one pytree to a single ``.npz`` — atomically: a UNIQUE
    tmp name in the target directory, fsync, then rename.  A kill
    mid-write leaves only an orphan (`clean_orphans` pattern), never
    a partially-written file under the final name, and a later save
    can never rename a STALE tmp over a good checkpoint (the failure
    mode of the old fixed ``path + ".tmp"`` name)."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = os.path.join(d, _tmp_name() + ".npz")
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flatten_tree(tree))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise
    _fsync_path(d)


def restore(path: str, like: Any) -> Any:
    """Restore a `save` file into the structure of `like`.  Missing /
    unexpected / mis-shaped keys raise a :class:`CheckpointError`
    listing every offending path (never a bare KeyError)."""
    with np.load(path) as data:
        flat = dict(data)
    return _restore_flat(flat, like, where=path)


# ---------------------------------------------------------------------------
# manifest-based versioned checkpoints
# ---------------------------------------------------------------------------

def _ckpt_name(step: int) -> str:
    return f"{STEP_PREFIX}{step:08d}"


def checkpoint_steps(directory: str) -> list:
    """Steps of every COMMITTED checkpoint in ``directory`` (a
    ``step_*`` dir whose manifest file exists), ascending."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if not name.startswith(STEP_PREFIX):
            continue
        if os.path.exists(os.path.join(directory, name, MANIFEST_NAME)):
            try:
                steps.append(int(name[len(STEP_PREFIX):]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    """The newest committed checkpoint step, or None."""
    steps = checkpoint_steps(directory)
    return steps[-1] if steps else None


def _canonical(body: dict) -> bytes:
    return json.dumps(body, sort_keys=True,
                      separators=(",", ":")).encode()


def _comm_dict(comm) -> Optional[dict]:
    if comm is None:
        return None
    return comm.to_dict() if hasattr(comm, "to_dict") else dict(comm)


def save_state(directory: str, state: Any, *, step: int, comm=None,
               extra: Optional[dict] = None, keep: int = 0) -> str:
    """Commit the FULL train state as checkpoint ``step`` under
    ``directory``; returns the committed path.

    ``comm`` (a `repro.comm.CommConfig`, or its dict) is recorded so
    `restore_state` can refuse a config-mismatched resume with a
    field diff.  ``extra`` is free-form JSON metadata (PRNG key, data
    position, loss).  ``keep > 0`` rotates: after the commit only the
    newest ``keep`` checkpoints survive.  See the module docstring
    for the crash-safety protocol."""
    os.makedirs(directory, exist_ok=True)
    flat = flatten_tree(state)
    tmp = os.path.join(directory, _tmp_name())
    os.makedirs(tmp)
    try:
        npz_path = os.path.join(tmp, ARRAYS_NAME)
        with open(npz_path, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        with open(npz_path, "rb") as f:
            npz_sha = hashlib.sha256(f.read()).hexdigest()
        arrays = {}
        for key, shape, dtype in _struct_items(state):
            arr = flat[key]
            arrays[key] = {"shape": list(shape), "dtype": dtype,
                           "stored_dtype": str(arr.dtype),
                           "crc32": zlib.crc32(arr.tobytes())}
        body = {"format_version": FORMAT_VERSION, "step": int(step),
                "comm": _comm_dict(comm),
                "fingerprint": tree_fingerprint(state),
                "arrays": arrays, "npz_sha256": npz_sha,
                "extra": extra or {}}
        manifest = {"crc32": zlib.crc32(_canonical(body)), "body": body}
        mpath = os.path.join(tmp, MANIFEST_NAME)
        with open(mpath, "w") as f:
            json.dump(manifest, f, sort_keys=True,
                      separators=(",", ":"))
            f.flush()
            os.fsync(f.fileno())
        _fsync_path(tmp)
        final = os.path.join(directory, _ckpt_name(step))
        if os.path.exists(final):
            # replay after recovery re-commits an existing step: move
            # the old one aside first (a crash here leaves an orphan,
            # not a loss — the staged replacement is already durable)
            old = os.path.join(directory, _tmp_name())
            os.rename(final, old)
            os.rename(tmp, final)
            shutil.rmtree(old)
        else:
            os.rename(tmp, final)
    except BaseException:
        if os.path.isdir(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
        raise
    _fsync_path(directory)
    if keep > 0:
        for s in checkpoint_steps(directory)[:-keep]:
            victim = os.path.join(directory, _ckpt_name(s))
            doomed = os.path.join(directory, _tmp_name())
            os.rename(victim, doomed)     # crash here -> orphan
            shutil.rmtree(doomed)
    return os.path.join(directory, _ckpt_name(step))


def _load_manifest(ckpt_path: str) -> dict:
    mpath = os.path.join(ckpt_path, MANIFEST_NAME)
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise CheckpointError(f"{ckpt_path}: no {MANIFEST_NAME} — not "
                              f"a committed checkpoint")
    except json.JSONDecodeError as e:
        raise CheckpointError(f"{mpath}: manifest is corrupt (JSON "
                              f"parse failed: {e}); refusing to load")
    body, crc = manifest.get("body"), manifest.get("crc32")
    if body is None or crc != zlib.crc32(_canonical(body)):
        raise CheckpointError(f"{mpath}: manifest CRC mismatch — the "
                              f"file was corrupted after commit; "
                              f"refusing to load")
    if body.get("format_version") != FORMAT_VERSION:
        raise CheckpointError(
            f"{mpath}: format_version {body.get('format_version')!r} "
            f"!= supported {FORMAT_VERSION}")
    return body


def resolve_checkpoint(directory: str,
                       step: Optional[int] = None) -> str:
    """Path of the checkpoint to restore: ``directory`` itself if it
    IS a committed checkpoint, else its newest (or ``step``-selected)
    ``step_*`` child.  No committed checkpoint raises loudly."""
    if os.path.exists(os.path.join(directory, MANIFEST_NAME)):
        return directory
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise CheckpointError(
                f"{directory}: no committed checkpoint found "
                f"(nothing matching {STEP_PREFIX}*/{MANIFEST_NAME})")
    path = os.path.join(directory, _ckpt_name(step))
    if not os.path.exists(os.path.join(path, MANIFEST_NAME)):
        raise CheckpointError(f"{path}: no committed checkpoint at "
                              f"step {step}; available: "
                              f"{checkpoint_steps(directory)}")
    return path


def _diff_comm(stored: dict, live: dict) -> list:
    diffs = []

    def walk(a, b, prefix):
        for k in sorted(set(a) | set(b)):
            va, vb = a.get(k), b.get(k)
            if isinstance(va, dict) and isinstance(vb, dict):
                walk(va, vb, f"{prefix}{k}.")
            elif va != vb:
                diffs.append(f"  {prefix}{k}: checkpoint={va!r} "
                             f"run={vb!r}")
    walk(stored, live, "")
    return diffs


def restore_state(directory: str, like: Any, *,
                  step: Optional[int] = None, comm=None):
    """Load and VERIFY a committed checkpoint into the structure of
    ``like``; returns ``(state, manifest_body)``.

    Verification is fail-closed, in order: manifest CRC, whole-file
    npz SHA-256, per-array CRC32, structure fingerprint (mismatch
    raises the missing/unexpected/mismatched diff of `_restore_flat`),
    and — when ``comm`` is given — the stored `CommConfig` (mismatch
    raises a field-by-field diff).  A checkpoint that fails ANY check
    raises :class:`CheckpointError`; garbage is never returned."""
    path = resolve_checkpoint(directory, step)
    body = _load_manifest(path)
    npz_path = os.path.join(path, ARRAYS_NAME)
    try:
        with open(npz_path, "rb") as f:
            raw = f.read()
    except FileNotFoundError:
        raise CheckpointError(f"{path}: {ARRAYS_NAME} is missing")
    if hashlib.sha256(raw).hexdigest() != body["npz_sha256"]:
        raise CheckpointError(
            f"{npz_path}: SHA-256 mismatch vs manifest — the array "
            f"payload was corrupted after commit; refusing to load")
    with np.load(npz_path) as data:
        flat = dict(data)
    for key, meta in body["arrays"].items():
        if key not in flat:
            continue                       # structure diff handles it
        if zlib.crc32(flat[key].tobytes()) != meta["crc32"]:
            raise CheckpointError(
                f"{npz_path}: CRC32 mismatch on array {key!r} — "
                f"corrupt payload; refusing to load")
    if comm is not None and body.get("comm") is not None:
        live = _comm_dict(comm)
        if live != body["comm"]:
            raise CheckpointError(
                "checkpoint comm config != this run's comm config:\n"
                + "\n".join(_diff_comm(body["comm"], live))
                + "\n  pass the checkpoint's config (or a fresh "
                  "--ckpt-dir) to proceed")
    state = _restore_flat(flat, like, where=path,
                          stored_fp=body["fingerprint"])
    return state, body
