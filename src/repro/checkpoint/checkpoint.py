"""Checkpointing: flatten any pytree (params, optimizer state, AQ-SGD
message buffers) into a single .npz with path-encoded keys.  No orbax in
this container; numpy archives are portable and adequate."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":     # ml_dtypes (bf16/f8): store
            arr = arr.astype(np.float32)      # as f32, restore recasts
        flat[key] = arr
    return flat


def save(path: str, tree: Any) -> None:
    tmp = path + ".tmp"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (shapes must match)."""
    with np.load(path) as data:
        flat = dict(data)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_keys, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_keys)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        out.append(np.asarray(jnp.asarray(arr).astype(leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef.structure
                                        if hasattr(treedef, "structure")
                                        else treedef, out)
