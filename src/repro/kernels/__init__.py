"""Fused TPU (Pallas) kernels and their jnp oracles.

`quant_pack` holds the boundary-codec kernels (one HBM pass per wire
side), `ref` the bit-identical pure-jnp oracles, `ops` the
ragged-row-padding wrappers callers use, and `flash_attention` the
attention kernel family.  `REPRO_PALLAS_INTERPRET=1` (default) runs
everything in interpret mode on CPU containers.
"""
