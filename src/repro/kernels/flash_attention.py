"""Pallas TPU flash-attention (forward) kernel.

The §Roofline baselines show training/prefill are memory-bound on
attention score traffic — the XLA-lowered blockwise attention writes
(block_q × block_k) f32 score/probability tiles to HBM at every step.
This kernel keeps the whole online-softmax state in VMEM scratch:

  grid = (B·H, Sq/block_q, Sk/block_k)   (TPU grid iterates sequentially
                                          over the last axis, so scratch
                                          carries across k-blocks)
  q tile   (block_q, hd)   VMEM           k/v tiles (block_k, hd) VMEM
  scratch  m, l (block_q,) + acc (block_q, hd) f32

HBM traffic drops to q+k+v+o (the flash bound).  GQA is handled in the
index_map (k/v blocks are fetched from the shared kv head — no
materialized head repetition).  Supports causal masking, sliding window,
and gemma-style logit softcap.  Backward remains the JAX-level flash
custom_vjp (models/layers.py); a dedicated bwd kernel is future work.

Validated in interpret mode against ref.flash_attention_ref; on real
TPUs pass interpret=False.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e9


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, nk: int,
            causal: bool, window: int, softcap: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    vis = k_pos <= q_pos if causal else jnp.full(
        (block_q, block_k), True)
    vis &= k_pos > q_pos - window
    s = jnp.where(vis, s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())))
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "block_q",
                              "block_k", "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window: int = 10 ** 9, softcap: float = 0.0,
                        block_q: int = 256, block_k: int = 256,
                        interpret: bool = True):
    """q: (B, H, Sq, hd); k, v: (B, Hk, Sk, hd) with H % Hk == 0.
    Returns o: (B, H, Sq, hd)."""
    b, h, sq, hd = q.shape
    hk, sk = k.shape[1], k.shape[2]
    assert h % hk == 0 and sq % block_q == 0 and sk % block_k == 0, (
        q.shape, k.shape, block_q, block_k)
    groups = h // hk
    nq, nk = sq // block_q, sk // block_k
    qf = q.reshape(b * h, sq, hd)
    kf = k.reshape(b * hk, sk, hd)
    vf = v.reshape(b * hk, sk, hd)

    def kv_index(bh, qi, ki):
        # GQA: query head bh -> shared kv head (no repetition in HBM)
        return (bh // groups, ki, 0)

    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(hd), block_q=block_q,
        block_k=block_k, nk=nk, causal=causal, window=window,
        softcap=softcap)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, hd)
