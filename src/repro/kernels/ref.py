"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth
swept against in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def _codes_ref(x, scale, bits: int, u=None):
    levels = (1 << bits) - 1
    y = jnp.clip((x / scale + 1.0) * (0.5 * levels), 0.0, levels)
    if u is None:
        return jnp.round(y).astype(jnp.uint8)
    lo = jnp.floor(y)
    return (lo + (u < (y - lo)).astype(jnp.float32)).astype(jnp.uint8)


def _pack_ref(codes, bits: int):
    k = 8 // bits
    r, d = codes.shape
    grouped = codes.reshape(r, d // k, k).astype(jnp.uint32)
    shifts = jnp.arange(k, dtype=jnp.uint32) * bits
    return jnp.sum(grouped << shifts, axis=-1).astype(jnp.uint8)


def _dequant_ref(codes, scale, bits: int):
    """Same association as core.quantization.dequantize (2c - lv exact,
    trailing division) so the oracle is FMA-contraction-proof too."""
    levels = (1 << bits) - 1
    ic = codes.astype(jnp.float32) * 2.0 - float(levels)
    return (ic * scale) / levels


def delta_quantize_pack_ref(a, m, bits: int, u=None):
    """AQ-SGD sender side: delta -> rowwise absmax scale -> b-bit codes ->
    dense uint8 packing.  a, m: (R, d) float; u: optional uniform noise
    for stochastic rounding.  Returns (packed (R, d*b/8), scale (R, 1)
    f32, m_new (R, d) f32)."""
    delta = a.astype(jnp.float32) - m.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(delta), axis=-1, keepdims=True),
                        _EPS)
    codes = _codes_ref(delta, scale, bits, u)
    packed = _pack_ref(codes, bits)
    m_new = m.astype(jnp.float32) + _dequant_ref(codes, scale, bits)
    return packed, scale, m_new


def quantize_pack_ref(x, bits: int, u=None):
    """DirectQ/backward/buffer sender side: absmax -> codes -> packing."""
    x = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), _EPS)
    return _pack_ref(_codes_ref(x, scale, bits, u), bits), scale


def unpack_dequant_ref(packed, scale, bits: int):
    """Inverse of quantize_pack_ref (full packed width, no accumulate)."""
    return dequant_unpack_accumulate_ref(
        packed, scale, jnp.zeros((packed.shape[0],
                                  packed.shape[1] * (8 // bits))), bits)


def dequant_unpack_accumulate_ref(packed, scale, m, bits: int):
    """AQ-SGD receiver side: unpack -> dequantize -> m += delta.
    packed: (R, d*b/8) u8; scale (R, 1); m (R, d).  Returns m_new f32."""
    k = 8 // bits
    levels = (1 << bits) - 1
    shifts = jnp.arange(k, dtype=jnp.uint32) * bits
    mask = jnp.uint32(levels)
    vals = (packed[..., None].astype(jnp.uint32) >> shifts) & mask
    r = packed.shape[0]
    codes = vals.reshape(r, -1)
    return m.astype(jnp.float32) + _dequant_ref(codes, scale, bits)


def quantize_pack_scaled_ref(x, s, bits: int, u=None):
    """DP-gradient sender side: quantize with the caller-supplied
    (pmax-shared) rowwise scale, then pack.  Returns packed u8 only —
    the scale already lives on every worker."""
    x = x.astype(jnp.float32)
    scale = jnp.maximum(s.astype(jnp.float32), _EPS)
    return _pack_ref(_codes_ref(x, scale, bits, u), bits)


def unpack_codes_ref(packed, bits: int):
    """Wire payload -> int32 codes (the psum accumulator form)."""
    k = 8 // bits
    levels = (1 << bits) - 1
    shifts = jnp.arange(k, dtype=jnp.uint32) * bits
    vals = (packed[..., None].astype(jnp.uint32) >> shifts) \
        & jnp.uint32(levels)
    return vals.reshape(packed.shape[0], -1).astype(jnp.int32)


def quantize_codes_scaled_ref(x, s, bits: int, u=None, pack: bool = False):
    """Codes-only encode oracle: quantize against the supplied (shared)
    scale, emit int32 codes — and, with pack=True, also the packed u8
    wire payload (the ring sender's one-pass output)."""
    x = x.astype(jnp.float32)
    scale = jnp.maximum(s.astype(jnp.float32), _EPS)
    codes = _codes_ref(x, scale, bits, u)
    if pack:
        return _pack_ref(codes, bits), codes.astype(jnp.int32)
    return codes.astype(jnp.int32)


def unpack_accumulate_ref(packed, acc, bits: int):
    """Ring accumulate oracle: acc + unpack(packed) in int32."""
    return acc.astype(jnp.int32) + unpack_codes_ref(packed, bits)


def _sum_width_ref(bits: int, n: int) -> int:
    maxv = n * ((1 << bits) - 1)
    for sw in (1, 2, 4, 8, 16, 32):
        if maxv <= (1 << sw) - 1:
            return sw
    raise ValueError((bits, n))


def pack_sums_ref(total, bits: int, n: int):
    """Code-sum packing oracle: i32 sums over n workers -> u8 payload at
    the narrowest width holding n*(2**bits - 1)."""
    sw = _sum_width_ref(bits, n)
    t = total.astype(jnp.uint32)
    if sw <= 8:
        k = 8 // sw
        r, d = t.shape
        grouped = t.reshape(r, d // k, k)
        shifts = jnp.arange(k, dtype=jnp.uint32) * sw
        return jnp.sum(grouped << shifts, axis=-1).astype(jnp.uint8)
    nb = sw // 8
    shifts = jnp.arange(nb, dtype=jnp.uint32) * 8
    b = (t[..., None] >> shifts) & jnp.uint32(0xFF)
    return b.reshape(t.shape[0], -1).astype(jnp.uint8)


def unpack_sums_ref(packed, bits: int, n: int):
    """Inverse of pack_sums_ref (full packed width)."""
    sw = _sum_width_ref(bits, n)
    if sw <= 8:
        k = 8 // sw
        shifts = jnp.arange(k, dtype=jnp.uint32) * sw
        vals = (packed[..., None].astype(jnp.uint32) >> shifts) \
            & jnp.uint32((1 << sw) - 1)
        return vals.reshape(packed.shape[0], -1).astype(jnp.int32)
    nb = sw // 8
    shifts = jnp.arange(nb, dtype=jnp.uint32) * 8
    b = packed.astype(jnp.uint32).reshape(packed.shape[0], -1, nb)
    return jnp.sum(b << shifts, axis=-1).astype(jnp.int32)


def dequant_sum_mean_ref(total, s, bits: int, n: int):
    """Int32 code sum over n workers + shared scale -> mean gradient.
    Same association as _dequant_ref (2T - n*lv exact, trailing
    divisions) so the oracle is FMA-contraction-proof too."""
    levels = (1 << bits) - 1
    ic = total.astype(jnp.float32) * 2.0 - float(n * levels)
    return ((ic * s) / levels) / n


def flash_attention_ref(q, k, v, *, causal=True, window=10 ** 9,
                        softcap=0.0):
    """Dense attention oracle.  q,k,v: (B, H, S, hd) (head-major)."""
    b, h, s, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    pos = jnp.arange(s)
    vis = jnp.ones((s, s), bool)
    if causal:
        vis &= pos[None, :] <= pos[:, None]
    vis &= pos[None, :] > pos[:, None] - window
    logits = jnp.where(vis, logits, -1e9)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
