"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth
swept against in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def delta_quantize_pack_ref(a, m, bits: int):
    """AQ-SGD sender side: delta -> rowwise absmax scale -> b-bit codes ->
    dense uint8 packing.  a, m: (R, d) float.  Returns (packed (R, d*b/8),
    scale (R, 1) f32, m_new (R, d) f32)."""
    delta = a.astype(jnp.float32) - m.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(delta), axis=-1, keepdims=True),
                        _EPS)
    levels = (1 << bits) - 1
    y = jnp.clip((delta / scale + 1.0) * (0.5 * levels), 0.0, levels)
    codes = jnp.round(y).astype(jnp.uint8)
    k = 8 // bits
    r, d = codes.shape
    grouped = codes.reshape(r, d // k, k).astype(jnp.uint32)
    shifts = jnp.arange(k, dtype=jnp.uint32) * bits
    packed = jnp.sum(grouped << shifts, axis=-1).astype(jnp.uint8)
    deq = (codes.astype(jnp.float32) * (2.0 / levels) - 1.0) * scale
    m_new = m.astype(jnp.float32) + deq
    return packed, scale, m_new


def dequant_unpack_accumulate_ref(packed, scale, m, bits: int):
    """AQ-SGD receiver side: unpack -> dequantize -> m += delta.
    packed: (R, d*b/8) u8; scale (R, 1); m (R, d).  Returns m_new f32."""
    k = 8 // bits
    levels = (1 << bits) - 1
    shifts = jnp.arange(k, dtype=jnp.uint32) * bits
    mask = jnp.uint32(levels)
    vals = (packed[..., None].astype(jnp.uint32) >> shifts) & mask
    r = packed.shape[0]
    codes = vals.reshape(r, -1)
    deq = (codes.astype(jnp.float32) * (2.0 / levels) - 1.0) * scale
    return m.astype(jnp.float32) + deq


def flash_attention_ref(q, k, v, *, causal=True, window=10 ** 9,
                        softcap=0.0):
    """Dense attention oracle.  q,k,v: (B, H, S, hd) (head-major)."""
    b, h, s, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    pos = jnp.arange(s)
    vis = jnp.ones((s, s), bool)
    if causal:
        vis &= pos[None, :] <= pos[:, None]
    vis &= pos[None, :] > pos[:, None] - window
    logits = jnp.where(vis, logits, -1e9)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
