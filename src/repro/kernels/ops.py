"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the TPU
mosaic pipeline is the target); set REPRO_PALLAS_INTERPRET=0 on real
hardware.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels import quant_pack as _qp
from repro.kernels import flash_attention as _fa

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def boundary_compress(a, m, *, bits: int, block_r: int = 128):
    """Sender side of an AQ-SGD boundary: (a, m) -> (packed, scale, m_new).
    a, m: any (..., d); rows are flattened for the kernel grid."""
    shape = a.shape
    a2 = a.reshape(-1, shape[-1])
    m2 = m.reshape(-1, shape[-1])
    packed, scale, m_new = _qp.delta_quantize_pack(
        a2, m2, bits=bits, block_r=block_r, interpret=INTERPRET)
    return (packed.reshape(*shape[:-1], -1),
            scale.reshape(*shape[:-1], 1),
            m_new.reshape(shape))


def boundary_decompress(packed, scale, m, *, bits: int,
                        block_r: int = 128):
    """Receiver side: reconstruct m_new = m + dequant(unpack(packed))."""
    shape = m.shape
    out = _qp.dequant_unpack_accumulate(
        packed.reshape(-1, packed.shape[-1]),
        scale.reshape(-1, 1), m.reshape(-1, shape[-1]),
        bits=bits, block_r=block_r, interpret=INTERPRET)
    return out.reshape(shape)


def flash_attention(q, k, v, **kw):
    """(B, H, Sq, hd) x (B, Hk, Sk, hd) -> (B, H, Sq, hd)."""
    kw.setdefault("interpret", INTERPRET)
    return _fa.flash_attention_fwd(q, k, v, **kw)
