"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the TPU
mosaic pipeline is the target); set REPRO_PALLAS_INTERPRET=0 on real
hardware — this flag is the single switch point for every fused op
(snapshotted once at import via `repro.env.pallas_interpret`).

The wrappers flatten leading dims to the kernel's (rows, d) layout and
zero-pad ragged row counts up to a block multiple (padding rows are
independent under rowwise quantization and sliced off the outputs), so
callers may pass any (..., d) batch shape.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from repro import env
from repro.kernels import quant_pack as _qp
from repro.kernels import flash_attention as _fa

INTERPRET = env.pallas_interpret()


@functools.lru_cache(maxsize=1)
def oncore_prng_supported() -> bool:
    """Whether the opt-in on-core PRNG encode path can lower here.

    pltpu.prng_seed has no CPU interpret-mode lowering (jax 0.4.x), so
    on CPU containers this is False and the boundary layer refuses the
    REPRO_ONCORE_PRNG opt-in with a clear error instead of a lowering
    crash."""
    try:
        x = jnp.zeros((8, 16), jnp.float32)
        _qp.quantize_codes_scaled(
            x, jnp.ones((8, 1), jnp.float32),
            bits=8, seed=jnp.zeros((2,), jnp.int32),
            interpret=INTERPRET).block_until_ready()
        return True
    except Exception:
        return False


def _padded_rows(r: int, block_r: int) -> int:
    """Row count the kernel grid actually runs: a multiple of block_r
    (or of the 8-row f32 sublane when everything fits one block)."""
    if r >= block_r:
        return -(-r // block_r) * block_r
    return -(-r // 8) * 8


def _as_rows(x, d: int, block_r: int):
    """(..., d) -> (padded_rows, d) plus the live row count."""
    x2 = x.reshape(-1, d)
    r = x2.shape[0]
    rp = _padded_rows(r, block_r)
    if rp != r:
        x2 = jnp.pad(x2, ((0, rp - r), (0, 0)))
    return x2, r


def boundary_compress(a, m, u=None, *, bits: int, seed=None,
                      block_r: int = 128):
    """Sender side of an AQ-SGD boundary: (a, m) -> (packed, scale, m_new).
    a, m (and optional stochastic noise u): any (..., d).  seed: (2,)
    i32 selects the on-core PRNG path (TPU only) instead of u."""
    shape = a.shape
    d = shape[-1]
    a2, r = _as_rows(a, d, block_r)
    m2, _ = _as_rows(m, d, block_r)
    u2 = None if u is None else _as_rows(u, d, block_r)[0]
    packed, scale, m_new = _qp.delta_quantize_pack(
        a2, m2, u2, bits=bits, seed=seed, block_r=block_r,
        interpret=INTERPRET)
    return (packed[:r].reshape(*shape[:-1], -1),
            scale[:r].reshape(*shape[:-1], 1),
            m_new[:r].reshape(shape))


def boundary_decompress(packed, scale, m, *, bits: int,
                        block_r: int = 128):
    """Receiver side: reconstruct m_new = m + dequant(unpack(packed))."""
    shape = m.shape
    d = shape[-1]
    p2, r = _as_rows(packed, packed.shape[-1], block_r)
    s2, _ = _as_rows(scale, 1, block_r)
    m2, _ = _as_rows(m, d, block_r)
    out = _qp.dequant_unpack_accumulate(
        p2, s2, m2, bits=bits, block_r=block_r, interpret=INTERPRET)
    return out[:r].reshape(shape)


def quantize_pack(x, u=None, *, bits: int, seed=None, block_r: int = 128):
    """Fused absmax -> quantize -> pack for any (..., d) tensor: the
    DirectQ sender, backward-gradient quantize, and z-bit buffer write.
    seed: (2,) i32 selects the on-core PRNG path (TPU only)."""
    shape = x.shape
    d = shape[-1]
    x2, r = _as_rows(x, d, block_r)
    u2 = None if u is None else _as_rows(u, d, block_r)[0]
    packed, scale = _qp.quantize_pack(x2, u2, bits=bits, seed=seed,
                                      block_r=block_r, interpret=INTERPRET)
    return (packed[:r].reshape(*shape[:-1], -1),
            scale[:r].reshape(*shape[:-1], 1))


def unpack_dequant(packed, scale, *, bits: int, out_dtype=jnp.float32,
                   block_r: int = 128):
    """Fused unpack -> dequantize; inverse of quantize_pack."""
    shape = packed.shape
    pw = shape[-1]
    p2, r = _as_rows(packed, pw, block_r)
    s2, _ = _as_rows(scale, 1, block_r)
    out = _qp.unpack_dequant(p2, s2, bits=bits, out_dtype=out_dtype,
                             block_r=block_r, interpret=INTERPRET)
    return out[:r].reshape(*shape[:-1], out.shape[-1])


def quantize_pack_scaled(x, s, u=None, *, bits: int, block_r: int = 128):
    """Fused quantize-with-given-scale -> pack for any (..., d) tensor:
    the DP gradient-wire sender (scale is the pmax-shared rowwise scale
    of a compressed allreduce, so it is an input, not computed here)."""
    shape = x.shape
    d = shape[-1]
    x2, r = _as_rows(x, d, block_r)
    s2, _ = _as_rows(s, 1, block_r)
    u2 = None if u is None else _as_rows(u, d, block_r)[0]
    packed = _qp.quantize_pack_scaled(x2, s2, u2, bits=bits,
                                      block_r=block_r, interpret=INTERPRET)
    return packed[:r].reshape(*shape[:-1], -1)


def unpack_codes(packed, *, bits: int, block_r: int = 128):
    """Fused unpack to int32 codes for any (..., pw) payload — the
    code-domain form the gradient wire accumulates with ``psum``."""
    shape = packed.shape
    p2, r = _as_rows(packed, shape[-1], block_r)
    out = _qp.unpack_codes(p2, bits=bits, block_r=block_r,
                           interpret=INTERPRET)
    return out[:r].reshape(*shape[:-1], out.shape[-1])


def quantize_codes_scaled(x, s, u=None, *, bits: int, pack: bool = False,
                          seed=None, block_r: int = 128):
    """Codes-only encode for any (..., d) tensor: quantize against the
    supplied (pmax-shared) rowwise scale and emit the int32 accumulator
    codes — with pack=True the same pass also emits the packed u8 wire
    payload (ring sender).  seed: (2,) i32 selects the on-core PRNG
    path (TPU only) instead of an explicit noise tensor."""
    shape = x.shape
    d = shape[-1]
    x2, r = _as_rows(x, d, block_r)
    s2, _ = _as_rows(s, 1, block_r)
    u2 = None if u is None else _as_rows(u, d, block_r)[0]
    out = _qp.quantize_codes_scaled(x2, s2, u2, bits=bits, pack=pack,
                                    seed=seed, block_r=block_r,
                                    interpret=INTERPRET)
    if pack:
        packed, codes = out
        return (packed[:r].reshape(*shape[:-1], -1),
                codes[:r].reshape(shape))
    return out[:r].reshape(shape)


def unpack_accumulate(packed, acc, *, bits: int, block_r: int = 128):
    """Fused unpack + int32 accumulate for any (..., pw) payload — the
    ring's accumulate step.  acc: (..., pw * 8/bits) i32.  Padded rows
    accumulate zeros and are sliced off, so ragged (last) ring segments
    are safe."""
    shape = acc.shape
    p2, r = _as_rows(packed, packed.shape[-1], block_r)
    a2, _ = _as_rows(acc, acc.shape[-1], block_r)
    out = _qp.unpack_accumulate(p2, a2, bits=bits, block_r=block_r,
                                interpret=INTERPRET)
    return out[:r].reshape(shape)


def pack_sums(total, *, bits: int, n: int, block_r: int = 128):
    """Dense code-sum packing for any (..., d) i32 sum tensor — the
    ring's all-gather payload (`Q.sum_wire_bits(bits, n)` bits/sum)."""
    shape = total.shape
    t2, r = _as_rows(total, shape[-1], block_r)
    out = _qp.pack_sums(t2, bits=bits, n=n, block_r=block_r,
                        interpret=INTERPRET)
    return out[:r].reshape(*shape[:-1], out.shape[-1])


def unpack_sums(packed, *, bits: int, n: int, block_r: int = 128):
    """Inverse of `pack_sums` for any (..., pw) payload."""
    shape = packed.shape
    p2, r = _as_rows(packed, shape[-1], block_r)
    out = _qp.unpack_sums(p2, bits=bits, n=n, block_r=block_r,
                          interpret=INTERPRET)
    return out[:r].reshape(*shape[:-1], out.shape[-1])


def dequant_sum_mean(total, s, *, bits: int, n: int, block_r: int = 128):
    """Fused int32-code-sum -> mean values for any (..., d) sum tensor:
    the DP gradient-wire receiver (padded rows carry zero scales and are
    sliced off, so ragged gradient buckets are safe)."""
    shape = total.shape
    d = shape[-1]
    t2, r = _as_rows(total, d, block_r)
    s2, _ = _as_rows(s, 1, block_r)
    out = _qp.dequant_sum_mean(t2, s2, bits=bits, n=n, block_r=block_r,
                               interpret=INTERPRET)
    return out[:r].reshape(shape)


def flash_attention(q, k, v, **kw):
    """(B, H, Sq, hd) x (B, Hk, Sk, hd) -> (B, H, Sq, hd)."""
    kw.setdefault("interpret", INTERPRET)
    return _fa.flash_attention_fwd(q, k, v, **kw)
