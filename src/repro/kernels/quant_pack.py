"""Pallas TPU kernels for the AQ-SGD boundary hot path.

The per-boundary critical path is: delta = a − m; rowwise absmax scale;
b-bit quantize; dense bit-pack (sender) and unpack; dequantize; buffer
accumulate (receiver).  Unfused, this chain makes ~6 HBM round-trips over
the activation; each kernel below fuses its whole side into ONE pass
(read a,m → write packed, scale, m_new), which is what makes compression
free on the compute critical path (paper §3.3).

TPU mapping: rows (tokens) are tiled along the grid; each grid step holds
a (BLOCK_R, d) tile in VMEM — d (the model dim, ≤ 8 KiB per row in bf16)
stays whole so the rowwise absmax is a single in-VMEM reduction, and the
lane dimension stays 128-aligned for the VPU.  Packing uses u32 shifts on
the (BLOCK_R, d/k, k) view.

Kernels are validated against ref.py in interpret mode (CPU container);
on real TPUs drop interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12
DEFAULT_BLOCK_R = 128


def _levels(bits: int) -> int:
    return (1 << bits) - 1


# ---------------------------------------------------------------------------
# sender: delta -> quantize -> pack (+ buffer update)
# ---------------------------------------------------------------------------

def _dqp_kernel(a_ref, m_ref, packed_ref, scale_ref, mnew_ref, *,
                bits: int):
    a = a_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    delta = a - m
    scale = jnp.maximum(jnp.max(jnp.abs(delta), axis=-1, keepdims=True),
                        _EPS)
    lv = _levels(bits)
    y = jnp.clip((delta / scale + 1.0) * (0.5 * lv), 0.0, lv)
    codes = jnp.round(y).astype(jnp.uint32)
    k = 8 // bits
    r, d = codes.shape
    grouped = codes.reshape(r, d // k, k)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)[None, None, :]
    packed_ref[...] = jnp.sum(grouped << shifts, axis=-1).astype(jnp.uint8)
    scale_ref[...] = scale
    deq = (codes.astype(jnp.float32) * (2.0 / lv) - 1.0) * scale
    mnew_ref[...] = (m + deq).astype(mnew_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block_r",
                                             "interpret"))
def delta_quantize_pack(a, m, *, bits: int, block_r: int = DEFAULT_BLOCK_R,
                        interpret: bool = True):
    """a, m: (R, d).  Returns (packed (R, d//(8/bits)) u8, scale (R, 1)
    f32, m_new (R, d) f32)."""
    assert bits in (2, 4, 8), bits
    r, d = a.shape
    k = 8 // bits
    assert d % k == 0, (d, bits)
    assert r % block_r == 0 or r < block_r, (r, block_r)
    br = min(block_r, r)
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_dqp_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, d // k), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, d // k), jnp.uint8),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, d), jnp.float32),
        ],
        interpret=interpret,
    )(a, m)


# ---------------------------------------------------------------------------
# receiver: unpack -> dequantize -> accumulate into the buffer replica
# ---------------------------------------------------------------------------

def _dua_kernel(packed_ref, scale_ref, m_ref, mnew_ref, *, bits: int):
    packed = packed_ref[...].astype(jnp.uint32)
    scale = scale_ref[...]
    m = m_ref[...].astype(jnp.float32)
    k = 8 // bits
    lv = _levels(bits)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)[None, None, :]
    vals = (packed[..., None] >> shifts) & jnp.uint32(lv)
    r = packed.shape[0]
    codes = vals.reshape(r, -1)
    deq = (codes.astype(jnp.float32) * (2.0 / lv) - 1.0) * scale
    mnew_ref[...] = (m + deq).astype(mnew_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block_r",
                                             "interpret"))
def dequant_unpack_accumulate(packed, scale, m, *, bits: int,
                              block_r: int = DEFAULT_BLOCK_R,
                              interpret: bool = True):
    """packed (R, d//(8/bits)) u8, scale (R, 1) f32, m (R, d).
    Returns m_new (R, d) f32 — the receiver's reconstructed activation."""
    assert bits in (2, 4, 8), bits
    r, d = m.shape
    k = 8 // bits
    br = min(block_r, r)
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_dua_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d // k), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.float32),
        interpret=interpret,
    )(packed, scale, m)
