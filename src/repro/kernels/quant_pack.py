"""Pallas TPU kernels for the AQ-SGD boundary hot path.

The per-boundary critical path is: delta = a − m; rowwise absmax scale;
b-bit quantize; dense bit-pack (sender) and unpack; dequantize; buffer
accumulate (receiver).  Unfused, this chain makes ~6 HBM round-trips over
the activation; each kernel below fuses its whole side into ONE pass
(read a,m → write packed, scale, m_new), which is what makes compression
free on the compute critical path (paper §3.3).

Four fused ops cover every boundary crossing in the pipeline:

* ``delta_quantize_pack``      — AQ-SGD sender (delta → wire + m_new);
* ``dequant_unpack_accumulate``— AQ-SGD receiver (wire + m → m_new);
* ``quantize_pack``            — DirectQ sender, backward-gradient
                                 quantize, and z-bit buffer writes;
* ``unpack_dequant``           — the matching receiver / buffer read.

Three further variants carry the data-parallel *gradient* wire
(core.grad_compress / core.collectives — the paper's Fig. 5
"end-to-end communication compression"):

* ``quantize_pack_scaled``     — quantize with a caller-supplied rowwise
                                 scale (the pmax-shared scale of a
                                 compressed allreduce) and pack;
* ``unpack_codes``             — unpack the wire payload to int32 codes
                                 (the code-domain ``psum`` accumulator);
* ``dequant_sum_mean``         — turn the int32 code *sum* over n
                                 workers back into the mean gradient.

Three more carry the *ring* form of that wire
(`core.collectives.ring_ef_reduce_mean_bucket` — packed codes on the
ppermute hops, local accumulation):

* ``quantize_codes_scaled``     — codes-only encode (optionally also
                                  packed): one pass emits the int32
                                  accumulator form and, for the ring,
                                  the packed wire payload — no on-device
                                  pack→unpack round trip;
* ``unpack_accumulate``         — the ring's accumulate step: unpack an
                                  incoming packed segment and add it to
                                  the local int32 code accumulator in
                                  one pass;
* ``pack_sums`` / ``unpack_sums`` — the ring's all-gather payload: code
                                  *sums* packed at the narrowest width
                                  holding n*(2**b - 1)
                                  (`Q.sum_wire_bits`).

Stochastic rounding takes the uniform noise tensor as an explicit kernel
input rather than seeding the on-core PRNG (pltpu.prng_random_bits): the
reference jnp backend consumes the *same* noise, which is what makes the
two backends bit-identical — the contract tests/test_boundary_parity.py
enforces.  On real TPUs the noise input costs one extra HBM read; the
encode kernels therefore also accept an OPT-IN ``seed`` path
(`REPRO_ONCORE_PRNG=1` at the boundary layer) that draws the uniform
noise on-core via ``pltpu.prng_seed``/``prng_random_bits`` instead.
That path relaxes the ref↔pallas contract to a statistical one (gated
by a dedicated 10k-trial unbiasedness test in test_grad_compress.py)
and is TPU-only: interpret mode has no CPU lowering for ``prng_seed``
(`repro.kernels.ops.oncore_prng_supported` probes for it).

TPU mapping: rows (tokens) are tiled along the grid; each grid step holds
a (BLOCK_R, d) tile in VMEM — d (the model dim, ≤ 8 KiB per row in bf16)
stays whole so the rowwise absmax is a single in-VMEM reduction, and the
lane dimension stays 128-aligned for the VPU.  Packing uses u32 shifts on
the (BLOCK_R, d/k, k) view.

Kernels are validated against ref.py in interpret mode (CPU container);
on real TPUs drop interpret=True — `repro.kernels.ops.INTERPRET`
(REPRO_PALLAS_INTERPRET=0) is the single switch point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_EPS = 1e-12
DEFAULT_BLOCK_R = 128


def _oncore_uniform(shape, seed_ref):
    """Uniform(0,1) drawn from the on-core PRNG (TPU only).

    Seeds with the two key words plus the grid position, so every block
    gets an independent stream; 24 mantissa bits of each u32 give an
    exact-in-f32 uniform on {0, ..., 2**24-1} / 2**24."""
    pltpu.prng_seed(seed_ref[0], seed_ref[1], pl.program_id(0))
    rb = pltpu.bitcast(pltpu.prng_random_bits(shape), jnp.uint32)
    return (rb >> 8).astype(jnp.float32) * (1.0 / (1 << 24))


def _seed_spec():
    """BlockSpec for the (2,) i32 seed of the on-core PRNG path."""
    return pl.BlockSpec(memory_space=pltpu.SMEM)


def _noise_arg(u, seed, row_spec):
    """Shared plumbing for the encode entry points: at most one of
    (u, seed) may be given.  Returns (extra_args, extra_specs, mode)."""
    assert u is None or seed is None, "pass uniform noise OR a PRNG seed"
    if u is not None:
        return [u], [row_spec], "input"
    if seed is not None:
        return [jnp.asarray(seed, jnp.int32)], [_seed_spec()], "oncore"
    return [], [], "none"


def _kernel_noise(noise, rest, shape):
    """Pop the noise operand (if any) off `rest` and realize the uniform
    tensor for `_quant_codes`; `shape` is the block's value shape."""
    rest = list(rest)
    if noise == "input":
        return rest.pop(0)[...], rest
    if noise == "oncore":
        return _oncore_uniform(shape, rest.pop(0)), rest
    return None, rest


def _levels(bits: int) -> int:
    return (1 << bits) - 1


def _quant_codes(x, scale, bits: int, u=None):
    """f32 values + rowwise scale -> u32 codes on the uniform grid.

    u: uniform(0,1) noise of x.shape for stochastic rounding (the same
    comparison `u < frac` as jax.random.bernoulli, so codes match the
    reference backend bit-for-bit); None = round-to-nearest.
    """
    lv = _levels(bits)
    y = jnp.clip((x / scale + 1.0) * (0.5 * lv), 0.0, lv)
    if u is None:
        return jnp.round(y).astype(jnp.uint32)
    lo = jnp.floor(y)
    bump = (u < (y - lo)).astype(jnp.float32)
    return (lo + bump).astype(jnp.uint32)


def _pack(codes, bits: int):
    """(r, d) u32 codes -> (r, d*bits/8) u8, k codes per byte."""
    k = 8 // bits
    r, d = codes.shape
    grouped = codes.reshape(r, d // k, k)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)[None, None, :]
    return jnp.sum(grouped << shifts, axis=-1).astype(jnp.uint8)


def _unpack(packed, bits: int):
    """(r, pw) u8 -> (r, pw * 8/bits) u32 codes."""
    k = 8 // bits
    lv = _levels(bits)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)[None, None, :]
    vals = (packed.astype(jnp.uint32)[..., None] >> shifts) & jnp.uint32(lv)
    return vals.reshape(packed.shape[0], -1)


def _dequant(codes, scale, bits: int):
    # must mirror core.quantization.dequantize op-for-op: 2c - lv is
    # integer-exact and the trailing division blocks FMA contraction, so
    # the fused kernel and the reference chain round identically under
    # any compiler (the bit-identical backend contract).
    lv = _levels(bits)
    ic = codes.astype(jnp.float32) * 2.0 - float(lv)
    return (ic * scale) / lv


# ---------------------------------------------------------------------------
# AQ-SGD sender: delta -> quantize -> pack (+ buffer update)
# ---------------------------------------------------------------------------

def _dqp_kernel(a_ref, m_ref, *rest, bits: int, noise: str):
    u, (packed_ref, scale_ref, mnew_ref) = _kernel_noise(
        noise, rest, a_ref.shape)
    a = a_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    delta = a - m
    scale = jnp.maximum(jnp.max(jnp.abs(delta), axis=-1, keepdims=True),
                        _EPS)
    codes = _quant_codes(delta, scale, bits, u)
    packed_ref[...] = _pack(codes, bits)
    scale_ref[...] = scale
    mnew_ref[...] = (m + _dequant(codes, scale, bits)).astype(mnew_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block_r",
                                             "interpret"))
def delta_quantize_pack(a, m, u=None, *, bits: int, seed=None,
                        block_r: int = DEFAULT_BLOCK_R,
                        interpret: bool = True):
    """a, m: (R, d); u: optional uniform noise (R, d) for stochastic
    rounding (or seed: (2,) i32 for the on-core PRNG path, TPU only).
    Returns (packed (R, d//(8/bits)) u8, scale (R, 1) f32,
    m_new (R, d) f32)."""
    assert bits in (2, 4, 8), bits
    r, d = a.shape
    k = 8 // bits
    assert d % k == 0, (d, bits)
    assert r % block_r == 0 or r < block_r, (r, block_r)
    br = min(block_r, r)
    grid = (r // br,)
    row_spec = pl.BlockSpec((br, d), lambda i: (i, 0))
    nargs, nspecs, noise = _noise_arg(u, seed, row_spec)
    in_specs = [row_spec, row_spec] + nspecs
    args = [a, m] + nargs
    return pl.pallas_call(
        functools.partial(_dqp_kernel, bits=bits, noise=noise),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((br, d // k), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, d // k), jnp.uint8),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, d), jnp.float32),
        ],
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# AQ-SGD receiver: unpack -> dequantize -> accumulate into the buffer
# ---------------------------------------------------------------------------

def _dua_kernel(packed_ref, scale_ref, m_ref, mnew_ref, *, bits: int):
    codes = _unpack(packed_ref[...], bits)
    m = m_ref[...].astype(jnp.float32)
    mnew_ref[...] = (m + _dequant(codes, scale_ref[...], bits)
                     ).astype(mnew_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block_r",
                                             "interpret"))
def dequant_unpack_accumulate(packed, scale, m, *, bits: int,
                              block_r: int = DEFAULT_BLOCK_R,
                              interpret: bool = True):
    """packed (R, d//(8/bits)) u8, scale (R, 1) f32, m (R, d).
    Returns m_new (R, d) f32 — the receiver's reconstructed activation."""
    assert bits in (2, 4, 8), bits
    r, d = m.shape
    k = 8 // bits
    assert r % block_r == 0 or r < block_r, (r, block_r)
    br = min(block_r, r)
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_dua_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d // k), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.float32),
        interpret=interpret,
    )(packed, scale, m)


# ---------------------------------------------------------------------------
# DirectQ / backward-gradient / buffer codec: absmax -> quantize -> pack
# ---------------------------------------------------------------------------

def _qp_kernel(x_ref, *rest, bits: int, noise: str):
    u, (packed_ref, scale_ref) = _kernel_noise(noise, rest, x_ref.shape)
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), _EPS)
    packed_ref[...] = _pack(_quant_codes(x, scale, bits, u), bits)
    scale_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bits", "block_r",
                                             "interpret"))
def quantize_pack(x, u=None, *, bits: int, seed=None,
                  block_r: int = DEFAULT_BLOCK_R,
                  interpret: bool = True):
    """x: (R, d); u: optional uniform noise (R, d) (or seed: (2,) i32
    for the on-core PRNG path, TPU only).  Returns
    (packed (R, d//(8/bits)) u8, scale (R, 1) f32) — one fused pass for
    the DirectQ sender, backward-gradient quantize, and z-bit buffer
    writes."""
    assert bits in (2, 4, 8), bits
    r, d = x.shape
    k = 8 // bits
    assert d % k == 0, (d, bits)
    assert r % block_r == 0 or r < block_r, (r, block_r)
    br = min(block_r, r)
    grid = (r // br,)
    row_spec = pl.BlockSpec((br, d), lambda i: (i, 0))
    nargs, nspecs, noise = _noise_arg(u, seed, row_spec)
    in_specs = [row_spec] + nspecs
    args = [x] + nargs
    return pl.pallas_call(
        functools.partial(_qp_kernel, bits=bits, noise=noise),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((br, d // k), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, d // k), jnp.uint8),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*args)

def _ud_kernel(packed_ref, scale_ref, out_ref, *, bits: int):
    codes = _unpack(packed_ref[...], bits)
    out_ref[...] = _dequant(codes, scale_ref[...], bits
                            ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block_r", "out_dtype",
                                             "interpret"))
def unpack_dequant(packed, scale, *, bits: int, out_dtype=jnp.float32,
                   block_r: int = DEFAULT_BLOCK_R, interpret: bool = True):
    """packed (R, pw) u8, scale (R, 1) f32 -> values (R, pw * 8/bits) in
    out_dtype — one fused pass for the DirectQ/backward receiver and
    z-bit buffer reads."""
    assert bits in (2, 4, 8), bits
    r, pw = packed.shape
    k = 8 // bits
    d = pw * k
    assert r % block_r == 0 or r < block_r, (r, block_r)
    br = min(block_r, r)
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_ud_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, pw), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.dtype(out_dtype)),
        interpret=interpret,
    )(packed, scale)


# ---------------------------------------------------------------------------
# DP gradient wire: shared-scale quantize, code-domain psum, sum -> mean
# ---------------------------------------------------------------------------

def _qps_kernel(x_ref, s_ref, *rest, bits: int, stochastic: bool):
    if stochastic:
        u_ref, packed_ref = rest
        u = u_ref[...]
    else:
        (packed_ref,) = rest
        u = None
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(s_ref[...].astype(jnp.float32), _EPS)
    packed_ref[...] = _pack(_quant_codes(x, scale, bits, u), bits)


@functools.partial(jax.jit, static_argnames=("bits", "block_r",
                                             "interpret"))
def quantize_pack_scaled(x, s, u=None, *, bits: int,
                         block_r: int = DEFAULT_BLOCK_R,
                         interpret: bool = True):
    """x: (R, d) values, s: (R, 1) caller-supplied rowwise scale (e.g. the
    pmax-shared scale of a compressed allreduce); u: optional uniform
    noise (R, d).  Returns packed (R, d//(8/bits)) u8 — one fused pass
    for the error-feedback gradient sender."""
    assert bits in (2, 4, 8), bits
    r, d = x.shape
    k = 8 // bits
    assert d % k == 0, (d, bits)
    assert r % block_r == 0 or r < block_r, (r, block_r)
    br = min(block_r, r)
    grid = (r // br,)
    row_spec = pl.BlockSpec((br, d), lambda i: (i, 0))
    in_specs = [row_spec, pl.BlockSpec((br, 1), lambda i: (i, 0))]
    args = [x, s]
    if u is not None:
        in_specs.append(row_spec)
        args.append(u)
    return pl.pallas_call(
        functools.partial(_qps_kernel, bits=bits, stochastic=u is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((br, d // k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d // k), jnp.uint8),
        interpret=interpret,
    )(*args)


def _uc_kernel(packed_ref, out_ref, *, bits: int):
    out_ref[...] = _unpack(packed_ref[...], bits).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits", "block_r",
                                             "interpret"))
def unpack_codes(packed, *, bits: int, block_r: int = DEFAULT_BLOCK_R,
                 interpret: bool = True):
    """packed (R, pw) u8 -> (R, pw * 8/bits) int32 codes: the code-domain
    form a compressed allreduce accumulates with ``psum`` (int32 sums of
    b-bit codes are exact in any reduction order)."""
    assert bits in (2, 4, 8), bits
    r, pw = packed.shape
    k = 8 // bits
    d = pw * k
    assert r % block_r == 0 or r < block_r, (r, block_r)
    br = min(block_r, r)
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_uc_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((br, pw), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.int32),
        interpret=interpret,
    )(packed)


def _dsm_kernel(total_ref, s_ref, out_ref, *, bits: int, n: int):
    # mean of n dequantized code tensors, given their exact int32 sum:
    #   sum_i ((2 c_i - lv) s) / lv = ((2 T - n lv) s) / lv
    # 2T - n*lv is integer-exact in f32 and the trailing divisions block
    # FMA contraction — same association as _dequant, so the reference
    # chain and this kernel round identically (the parity contract).
    lv = _levels(bits)
    ic = total_ref[...].astype(jnp.float32) * 2.0 - float(n * lv)
    out_ref[...] = ((ic * s_ref[...]) / lv) / n


@functools.partial(jax.jit, static_argnames=("bits", "n", "block_r",
                                             "interpret"))
def dequant_sum_mean(total, s, *, bits: int, n: int,
                     block_r: int = DEFAULT_BLOCK_R,
                     interpret: bool = True):
    """total (R, d) int32 code sum over n workers, s (R, 1) shared scale.
    Returns the mean gradient (R, d) f32 — the receiver side of the
    compressed DP allreduce."""
    assert bits in (2, 4, 8), bits
    assert isinstance(n, int) and n >= 1, n
    r, d = total.shape
    assert r % block_r == 0 or r < block_r, (r, block_r)
    br = min(block_r, r)
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_dsm_kernel, bits=bits, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.float32),
        interpret=interpret,
    )(total, s)


# ---------------------------------------------------------------------------
# compressed ring collective: codes-only encode, unpack-accumulate,
# code-sum pack/unpack (core.collectives.ring_ef_reduce_mean_bucket)
# ---------------------------------------------------------------------------

def _qcs_kernel(x_ref, s_ref, *rest, bits: int, noise: str, pack: bool):
    u, outs = _kernel_noise(noise, rest, x_ref.shape)
    if pack:
        packed_ref, codes_ref = outs
    else:
        (codes_ref,) = outs
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(s_ref[...].astype(jnp.float32), _EPS)
    codes = _quant_codes(x, scale, bits, u)
    if pack:
        packed_ref[...] = _pack(codes, bits)
    codes_ref[...] = codes.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits", "pack", "block_r",
                                             "interpret"))
def quantize_codes_scaled(x, s, u=None, *, bits: int, pack: bool = False,
                          seed=None, block_r: int = DEFAULT_BLOCK_R,
                          interpret: bool = True):
    """Codes-only encode: quantize x (R, d) against the caller-supplied
    rowwise scale s (R, 1) and emit int32 codes — the accumulator form a
    compressed allreduce sums — WITHOUT the pack→unpack round trip of
    `quantize_pack_scaled` + `unpack_codes`.  With pack=True the same
    pass also emits the packed u8 wire payload (the ring's hop
    segments).  u: optional uniform noise (R, d) (or seed: (2,) i32 for
    the on-core PRNG path, TPU only).

    Returns codes (R, d) i32, or (packed (R, d//(8/bits)) u8, codes)."""
    assert bits in (2, 4, 8), bits
    r, d = x.shape
    k = 8 // bits
    assert d % k == 0, (d, bits)
    assert r % block_r == 0 or r < block_r, (r, block_r)
    br = min(block_r, r)
    grid = (r // br,)
    row_spec = pl.BlockSpec((br, d), lambda i: (i, 0))
    nargs, nspecs, noise = _noise_arg(u, seed, row_spec)
    in_specs = [row_spec, pl.BlockSpec((br, 1), lambda i: (i, 0))] + nspecs
    args = [x, s] + nargs
    out_specs = [pl.BlockSpec((br, d), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((r, d), jnp.int32)]
    if pack:
        out_specs = [pl.BlockSpec((br, d // k), lambda i: (i, 0))] \
            + out_specs
        out_shape = [jax.ShapeDtypeStruct((r, d // k), jnp.uint8)] \
            + out_shape
    out = pl.pallas_call(
        functools.partial(_qcs_kernel, bits=bits, noise=noise, pack=pack),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    return tuple(out) if pack else out[0]


def _ua_kernel(packed_ref, acc_ref, out_ref, *, bits: int):
    out_ref[...] = acc_ref[...] + _unpack(packed_ref[...], bits
                                          ).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits", "block_r",
                                             "interpret"))
def unpack_accumulate(packed, acc, *, bits: int,
                      block_r: int = DEFAULT_BLOCK_R,
                      interpret: bool = True):
    """packed (R, pw) u8 incoming ring segment, acc (R, pw * 8/bits) i32
    local code accumulator.  Returns acc + unpack(packed) in ONE pass —
    the ring's accumulate step (the unpack the psum wire used to run as
    a separate op now rides the accumulation's HBM traffic)."""
    assert bits in (2, 4, 8), bits
    r, pw = packed.shape
    k = 8 // bits
    d = pw * k
    assert acc.shape == (r, d), (acc.shape, r, d)
    assert r % block_r == 0 or r < block_r, (r, block_r)
    br = min(block_r, r)
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_ua_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, pw), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.int32),
        interpret=interpret,
    )(packed, acc)


def _sum_geometry(bits: int, n: int) -> int:
    """Sum packing width in bits — mirrors
    core.quantization.sum_wire_bits."""
    maxv = n * _levels(bits)
    for sw in (1, 2, 4, 8, 16, 32):
        if maxv <= (1 << sw) - 1:
            return sw
    raise ValueError((bits, n))


def _ps_kernel(total_ref, out_ref, *, sw: int):
    t = total_ref[...].astype(jnp.uint32)
    if sw <= 8:
        out_ref[...] = _pack(t, sw)
    else:
        nb = sw // 8
        shifts = (jnp.arange(nb, dtype=jnp.uint32) * 8)[None, None, :]
        b = (t[..., None] >> shifts) & jnp.uint32(0xFF)
        out_ref[...] = b.reshape(t.shape[0], -1).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("bits", "n", "block_r",
                                             "interpret"))
def pack_sums(total, *, bits: int, n: int,
              block_r: int = DEFAULT_BLOCK_R, interpret: bool = True):
    """total (R, d) i32 code sums over n workers -> dense u8 payload at
    `sum_wire_bits(bits, n)` bits per sum — the ring's all-gather hop
    format (b + ceil(log2 n) bits is the exactness price of shipping
    sums instead of re-quantizing)."""
    assert bits in (2, 4, 8), bits
    sw = _sum_geometry(bits, n)
    r, d = total.shape
    if sw <= 8:
        k = 8 // sw
        assert d % k == 0, (d, sw)
        pw = d // k
    else:
        pw = d * (sw // 8)
    assert r % block_r == 0 or r < block_r, (r, block_r)
    br = min(block_r, r)
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_ps_kernel, sw=sw),
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, pw), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, pw), jnp.uint8),
        interpret=interpret,
    )(total)


def _us_kernel(packed_ref, out_ref, *, sw: int):
    p = packed_ref[...]
    if sw <= 8:
        out_ref[...] = _unpack(p, sw).astype(jnp.int32)
    else:
        nb = sw // 8
        shifts = (jnp.arange(nb, dtype=jnp.uint32) * 8)[None, None, :]
        b = p.astype(jnp.uint32).reshape(p.shape[0], -1, nb)
        out_ref[...] = jnp.sum(b << shifts, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits", "n", "block_r",
                                             "interpret"))
def unpack_sums(packed, *, bits: int, n: int,
                block_r: int = DEFAULT_BLOCK_R, interpret: bool = True):
    """Inverse of `pack_sums`: u8 payload -> (R, d) i32 code sums."""
    assert bits in (2, 4, 8), bits
    sw = _sum_geometry(bits, n)
    r, pw = packed.shape
    d = pw * (8 // sw) if sw <= 8 else pw // (sw // 8)
    assert r % block_r == 0 or r < block_r, (r, block_r)
    br = min(block_r, r)
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_us_kernel, sw=sw),
        grid=grid,
        in_specs=[pl.BlockSpec((br, pw), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.int32),
        interpret=interpret,
    )(packed)
