"""Render the §Dry-run / §Roofline tables in EXPERIMENTS.md from the
dryrun JSON artifacts.

Usage: python -m repro.launch.report results/dryrun_singlepod.json
"""
from __future__ import annotations

import json
import sys


def fmt_row(r) -> str:
    if r.get("skip"):
        return (f"| {r['arch']} | {r['shape']} | — | SKIP (DESIGN.md §5) "
                f"| | | | | | |")
    c, m, co = r["compute_s"], r["memory_s"], r["collective_s"]
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['flops_per_device']:.2e} | {r['bytes_per_device']:.2e} "
            f"| {r['coll_bytes_per_device']:.2e} "
            f"| {c*1e3:.0f} / {m*1e3:.0f} / {co*1e3:.0f} "
            f"| **{r['bottleneck']}** | {r['useful_ratio']:.2f} "
            f"| {r['hbm_args_gb'] + r['hbm_temps_gb']:.1f} |")


HEADER = ("| arch | shape | mesh | FLOPs/dev | bytes/dev | coll B/dev "
          "| comp/mem/coll (ms) | bottleneck | useful | HBM GB |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def main():
    for path in sys.argv[1:]:
        rows = json.load(open(path))
        print(f"\n### {path}\n")
        print(HEADER)
        for r in rows:
            print(fmt_row(r))


if __name__ == "__main__":
    main()
