import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

For each combination this builds the *real* distributed program —
the shard_map GPipe pipeline with AQ-SGD-compressed boundaries for
train_4k, the pjit-sharded prefill/serve steps for the inference
shapes — entirely from ShapeDtypeStructs (no allocation), compiles it for
the production mesh, and records:

  * memory_analysis()  — proves the program fits 16 GB/chip HBM,
  * cost_analysis()    — per-device FLOPs / bytes for §Roofline,
  * collective bytes   — parsed from the optimized HLO.

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.json
  python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.config import CommConfig
from repro.configs.base import (ARCHS, INPUT_SHAPES, ModelConfig,
                                get_config, shape_applies)
from repro.core.aqsgd import CompressionConfig
from repro.launch import analysis
from repro.launch.mesh import data_axes, make_production_mesh
from repro.models import model as Mo
from repro.optim.adamw import AdamWConfig
from repro.serving import decode as Sv
from repro.training import pipeline as PL


def _bf16_structs(tree):
    def cast(s):
        dt = jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating) \
            else s.dtype
        return jax.ShapeDtypeStruct(s.shape, dt)
    return jax.tree.map(cast, tree)


def input_specs(cfg: ModelConfig, shape, *, for_decode: bool):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b = shape.global_batch
    if for_decode:
        tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        extras = {}
    else:
        n_text = shape.seq_len - (cfg.num_patches or 0)
        tokens = jax.ShapeDtypeStruct((b, n_text), jnp.int32)
        extras = {}
        if cfg.family == "vlm":
            extras["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            extras["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return tokens, extras


def lower_serving(cfg: ModelConfig, mesh, shape, *, prefill: bool):
    params_shape = _bf16_structs(jax.eval_shape(
        lambda: Mo.init_params(cfg, jax.random.PRNGKey(0))))
    cache_shape = jax.eval_shape(
        lambda: Mo.init_caches(cfg, shape.global_batch, shape.seq_len,
                               jnp.bfloat16))
    tokens, extras = input_specs(cfg, shape, for_decode=not prefill)
    ps = Sv.param_shardings(cfg, mesh, params_shape)
    cs = Sv.cache_shardings(cfg, mesh, cache_shape)
    ts = Sv.batch_sharding(mesh, tokens.shape)
    ex_sh = {k: Sv.batch_sharding(mesh, v.shape) for k, v in extras.items()}
    logits_s = Sv.logits_sharding(cfg, mesh)

    def fn(params, caches, tokens, extras):
        return Mo.forward_with_caches(
            params, cfg, tokens, caches, logits_last_only=True, **extras)

    jitted = jax.jit(fn, in_shardings=(ps, cs, ts, ex_sh),
                     out_shardings=(logits_s, cs),
                     donate_argnums=(1,))       # cache updated in place
    return jitted.lower(params_shape, cache_shape, tokens, extras)


def lower_train(cfg: ModelConfig, mesh, shape, *,
                compression: str = "aqsgd", fw_bits: int = 4,
                bw_bits: int = 8, microbatches: int = 0,
                moe_mode: str = "zero3", opt_state_bits: int = 0,
                buffer_bits: int = 0):
    daxes = data_axes(mesh)
    d_repl = 1
    for a in daxes:
        d_repl *= mesh.shape[a]
    br = shape.global_batch // d_repl
    m = microbatches or br             # default microbatch size 1
    pcfg = PL.PipelineConfig(
        microbatches=m, moe_mode=moe_mode,
        comm=CommConfig.from_legacy(
            CompressionConfig(mode=compression, fw_bits=fw_bits,
                              bw_bits=bw_bits),
            buffer_bits=buffer_bits))
    step, meta = PL.make_train_step(
        cfg, pcfg, mesh, AdamWConfig(state_bits=opt_state_bits),
        global_batch=shape.global_batch,
        seq_len=shape.seq_len, buffer_samples=br)
    state, batch, key = PL.make_state_structs(
        cfg, pcfg, meta, mesh, global_batch=shape.global_batch,
        seq_len=shape.seq_len, opt_state_bits=opt_state_bits)
    return step.lower(state, batch, key)


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               compression: str = "aqsgd", microbatches: int = 0,
               verbose: bool = True, dump_hlo: str = "",
               moe_mode: str = "zero3", opt_state_bits: int = 0,
               buffer_bits: int = 0):
    cfg = get_config(arch).with_(dtype="bfloat16")
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    shape = INPUT_SHAPES[shape_name]
    t0 = time.time()
    if shape.kind == "train":
        lowered = lower_train(cfg, mesh, shape, compression=compression,
                              microbatches=microbatches, moe_mode=moe_mode,
                              opt_state_bits=opt_state_bits,
                              buffer_bits=buffer_bits)
    else:
        lowered = lower_serving(cfg, mesh, shape,
                                prefill=(shape.kind == "prefill"))
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mf = analysis.model_flops_estimate(cfg, shape.kind, shape.global_batch,
                                       shape.seq_len)
    roof = analysis.analyze_compiled(
        compiled, arch=arch, shape=shape_name,
        mesh_desc="2x16x16" if multi_pod else "16x16", chips=chips,
        model_flops=mf)
    ma = compiled.memory_analysis()
    if verbose:
        print(f"--- {arch} × {shape_name} × {roof.mesh} "
              f"(lower {t1-t0:.1f}s compile {t2-t1:.1f}s)")
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/1e9:.2f}GB"
              f" temps={ma.temp_size_in_bytes/1e9:.2f}GB"
              f" out={ma.output_size_in_bytes/1e9:.2f}GB per device")
        print(f"  cost_analysis:   flops/dev={roof.flops_per_device:.3e}"
              f" bytes/dev={roof.bytes_per_device:.3e}")
        print(f"  collectives/dev: {roof.coll_bytes_per_device:.3e} B "
              f"{ {k: int(v) for k, v in roof.coll_breakdown.items() if v} }")
        print(f"  roofline: compute={roof.compute_s*1e3:.2f}ms"
              f" memory={roof.memory_s*1e3:.2f}ms"
              f" collective={roof.collective_s*1e3:.2f}ms"
              f" -> {roof.bottleneck}-bound"
              f" useful={roof.useful_ratio:.2f}")
    if dump_hlo:
        with open(dump_hlo, "w") as f:
            f.write(compiled.as_text())
    d = roof.to_dict()
    d["hbm_args_gb"] = ma.argument_size_in_bytes / 1e9
    d["hbm_temps_gb"] = ma.temp_size_in_bytes / 1e9
    d["lower_s"] = t1 - t0
    d["compile_s"] = t2 - t1
    d["compression"] = compression if shape.kind == "train" else "n/a"
    return d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--compression", default="aqsgd",
                    choices=["fp32", "directq", "aqsgd"])
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--moe-mode", default="zero3",
                    choices=["zero3", "expert_parallel"])
    ap.add_argument("--opt-state-bits", type=int, default=0)
    ap.add_argument("--buffer-bits", type=int, default=0)
    ap.add_argument("--out", default="")
    ap.add_argument("--dump-hlo", default="")
    args = ap.parse_args()

    combos = []
    if args.all:
        for arch in ARCHS:
            if arch == "gpt2-xl-paper":
                continue               # the paper's own arch: use --arch
            for sh in INPUT_SHAPES:
                combos.append((arch, sh))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    rows, failures = [], []
    for arch, sh in combos:
        if not shape_applies(arch, sh):
            print(f"--- {arch} × {sh}: SKIP (see DESIGN.md §5)")
            rows.append({"arch": arch, "shape": sh, "skip": True})
            continue
        try:
            rows.append(dryrun_one(
                arch, sh, multi_pod=args.multi_pod,
                compression=args.compression,
                microbatches=args.microbatches, dump_hlo=args.dump_hlo,
                moe_mode=args.moe_mode,
                opt_state_bits=args.opt_state_bits,
                buffer_bits=args.buffer_bits))
        except Exception as e:          # noqa: BLE001
            traceback.print_exc()
            failures.append((arch, sh, str(e)[:300]))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print("wrote", args.out)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print(f"DRYRUN OK ({len(rows)} combos)")


if __name__ == "__main__":
    main()
