"""Fault-tolerant driver for the single-host simulated trainer.

`run_sim_training` wraps `training.simulated.train_step` with the full
ISSUE-8 recovery loop while reproducing its math EXACTLY — same key
discipline (``PRNGKey → split → (k_init, k_run)``, ``fold_in(k_run,
step)`` per step), same jitted step, same static configs — so a run
with checkpointing on is bit-identical to one with it off, and a
killed-and-resumed run replays the identical loss stream:

* **checkpoint** — every ``save_every`` steps (plus step 0 at init and
  the final step) the FULL state — params, opt (incl. segment-sharded
  moments), the AQ-SGD message buffers, the ``dp_error`` EF carry —
  is committed via `repro.checkpoint.save_state` together with the
  PRNG key data, the data-pipeline position, and the recent loss tail;
  ``keep`` rotates old checkpoints out;
* **resume** — `restore_state` verifies checksums + structure + comm
  config, the PRNG key data is CHECKED against the live seed (a
  resume under a different seed fails loudly instead of silently
  forking the trajectory), and the deterministic `data.pipeline`
  stream is replayed by skipping the first ``step`` batches;
* **inject** — a `repro.comm.faults.FaultPlan` fires at its (step,
  plane) coordinates: dp faults swap the internal fault-wrapper wire
  into a replaced static config for exactly that step (clean steps
  keep the original compiled executable), fw/bw/zbuf faults corrupt
  the carried state via `inject_sim_state`.  Each fault fires ONCE —
  the post-recovery replay of the same step runs clean;
* **recover** — after every step the loss (always) and the state
  (when a fault plan or checkpointing is active) pass through
  `check_train_state`; a `WireFaultError` reloads the last good
  checkpoint and replays, at most ``max_retries`` times, then
  re-raises.

``kill_at=k`` hard-exits the process (``os._exit(17)``) right after
printing step k's loss and BEFORE any save — the crash lands mid
checkpoint interval, which is exactly what the kill-and-resume
bit-parity gate needs to prove replay determinism.

Loss lines carry both the rounded value and ``float.hex()`` so the
CLI parity gates compare exact bits, not printed digits.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.comm import faults as F

KILL_EXIT_CODE = 17   # --kill-at's os._exit status: distinguishable
                      # from both success and a python traceback


def _key_data(key) -> np.ndarray:
    """Raw uint32 words of a PRNG key (typed or old-style)."""
    try:
        if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
            return np.asarray(jax.random.key_data(key))
    except (AttributeError, TypeError):
        pass
    return np.asarray(key)


def _skip_batches(dataset, batch_size: int, num_steps: int,
                  start: int):
    """The deterministic batch stream starting at step ``start`` —
    `Dataset.reset` rewinds the epoch-shuffle rng to its seed, so the
    stream is a pure function of the config and resume/replay is
    reset-and-skip, no cursor state to persist."""
    dataset.reset()
    it = dataset.batches(batch_size, num_steps)
    for _ in range(start):
        next(it)
    return it


def _loss_line(step: int, loss: float) -> str:
    return (f"step {step:5d} loss {loss:.4f} "
            f"[{float(loss).hex()}]")


def run_sim_training(mcfg, tcfg, dataset, *, num_steps: int,
                     batch_size: int, log_every: int = 10,
                     ckpt_dir: str = "", save_every: int = 0,
                     keep: int = 3, resume: bool = False,
                     max_retries: int = 2,
                     fault_plan: Optional[F.FaultPlan] = None,
                     kill_at: Optional[int] = None, key=None,
                     print_fn=print):
    """Run the simulated trainer with checkpoint/resume, deterministic
    fault injection, and guarded recovery (module docstring).  Returns
    ``(state, losses)`` where ``losses`` covers the steps THIS call
    executed (a resumed call starts at the checkpoint step).

    Math-identical to `training.simulated.train` — checkpointing off
    and an empty fault plan reproduce its loss stream bit-for-bit."""
    from repro.training import simulated as sim

    comm = tcfg.comm
    plan = fault_plan or F.FaultPlan()
    for spec in plan.faults:
        if spec.plane == "kv":
            raise ValueError("kv faults target the serving batcher "
                             "(launch.serve), not the trainer")
        if spec.plane == "dp" and not comm.dp.bits:
            raise ValueError(f"fault {spec.text()!r} needs "
                             f"--dp-grad-bits > 0")
        if spec.plane in ("fw", "zbuf") and comm.mode != "aqsgd":
            raise ValueError(f"fault {spec.text()!r} needs "
                             f"mode='aqsgd' (message buffers)")
        if spec.plane == "zbuf" and not comm.zbuf.bits:
            raise ValueError(f"fault {spec.text()!r} needs "
                             f"--buffer-bits > 0")
    if (plan or save_every or resume) and not ckpt_dir:
        if plan or resume:
            raise ValueError("--fault/--resume need --ckpt-dir")
    if ckpt_dir:
        removed = ckpt.clean_orphans(ckpt_dir)
        if removed:
            print_fn(f"checkpoint: removed {len(removed)} orphaned "
                     f"tmp entr{'y' if len(removed) == 1 else 'ies'}")

    key = key if key is not None else jax.random.PRNGKey(0)
    k_init, k_run = jax.random.split(key)
    state = sim.init_train_state(mcfg, tcfg, dataset.num_samples,
                                 dataset.dc.seq_len, k_init)
    save_tree = lambda st: {"state": st, "k_run": _key_data(k_run)}
    like = jax.eval_shape(save_tree, state)

    start, loss_tail = 0, []
    if resume:
        tree, body = ckpt.restore_state(ckpt_dir, like, comm=comm)
        if not np.array_equal(np.asarray(tree["k_run"]),
                              _key_data(k_run)):
            raise ckpt.CheckpointError(
                "checkpoint PRNG key != this run's seed — resuming "
                "would silently fork the trajectory")
        state, start = tree["state"], int(body["step"])
        loss_tail = list(body["extra"].get("losses_tail", []))
        print_fn(f"resumed from step {start} "
                 f"({ckpt.resolve_checkpoint(ckpt_dir)})")
    elif ckpt_dir and save_every:
        ckpt.save_state(ckpt_dir, save_tree(state), step=0, comm=comm,
                        extra={"losses_tail": [], "data_position": 0},
                        keep=keep)

    def save(step_done: int, tail: list):
        ckpt.save_state(
            ckpt_dir, save_tree(state), step=step_done, comm=comm,
            extra={"losses_tail": [float(x) for x in tail[-5:]],
                   "data_position": step_done}, keep=keep)

    guard_state = bool(plan or (ckpt_dir and save_every))
    it = _skip_batches(dataset, batch_size, num_steps, start)
    it_pos = start
    fired = {s for s in plan.faults if s.step < start}
    losses, retries, step = [], 0, start
    while step < num_steps:
        if it_pos != step:
            it = _skip_batches(dataset, batch_size, num_steps, step)
            it_pos = step
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        it_pos += 1

        step_tcfg = tcfg
        post_step = []
        for spec in plan.at(step):
            if spec in fired:
                continue
            fired.add(spec)
            print_fn(f"injecting fault {spec.text()}")
            if spec.plane == "dp":
                step_tcfg = tcfg.with_comm(F.faulted_comm(comm, spec))
            elif spec.plane == "bw":
                # a corrupt backward hop lands in the params at the
                # UPDATE — after the forward wrote clean messages —
                # so bw injection follows the step (guard attribution
                # depends on this timing; see faults.inject_sim_state)
                post_step.append(spec)
            else:
                state = F.inject_sim_state(state, spec, comm)

        state, metrics = sim.train_step(
            state, batch, jax.random.fold_in(k_run, step),
            mcfg=mcfg, tcfg=step_tcfg)
        for spec in post_step:
            state = F.inject_sim_state(state, spec, comm)
        loss = float(metrics["loss"])
        try:
            F.check_train_state(state if guard_state else {},
                                comm=comm, step=step, loss=loss)
        except F.WireFaultError as e:
            print_fn(f"guard tripped: {e}")
            retries += 1
            if not ckpt_dir or retries > max_retries:
                raise
            tree, body = ckpt.restore_state(ckpt_dir, like, comm=comm)
            state, step = tree["state"], int(body["step"])
            loss_tail = list(body["extra"].get("losses_tail", []))
            losses = [x for x in losses][:max(step - start, 0)]
            print_fn(f"recovered from checkpoint step {step} "
                     f"(retry {retries}/{max_retries})")
            continue

        losses.append(loss)
        loss_tail = (loss_tail + [loss])[-5:]
        if log_every and step % log_every == 0:
            print_fn(_loss_line(step, loss))
        if kill_at is not None and step == kill_at:
            print_fn(f"killing at step {step} (exit {KILL_EXIT_CODE})")
            # simulate a hard preemption: no save, no cleanup, no
            # python teardown — the next run must recover from the
            # last committed checkpoint alone
            os._exit(KILL_EXIT_CODE)
        step += 1
        if ckpt_dir and save_every and step % save_every == 0:
            save(step, loss_tail)

    if ckpt_dir and save_every and num_steps % save_every != 0:
        save(num_steps, loss_tail)
    return state, losses
