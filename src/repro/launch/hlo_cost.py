"""Loop-aware cost accounting over optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE —
useless for scan-heavy programs (our pipelines run 31-tick × per-layer
scans, so it undercounts ~100×).  Fortunately the optimized HLO carries
``backend_config={"known_trip_count":{"n":...}}`` on every while, so we
re-derive the three roofline inputs ourselves, exactly:

  flops      — 2·prod(result)·prod(contraction) per ``dot``, multiplied
               through enclosing while trip counts (recursing through
               fusions / calls / conditionals);
  bytes      — HBM traffic model: operand+result bytes of every
               *top-level* op (fusion interiors excluded — that is what
               fusion means), × trip counts;
  collective — operand/result bytes per collective kind, × trip counts.

Conditionals take the MAX across branches (a static analysis cannot know
branch frequencies; for zamba2's shared-attention flags this overcounts
the attention term — EXPERIMENTS.md notes the correction).

Validated against fully-unrolled compiles of reduced configs in
tests/test_hlo_cost.py.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

#: The collective HLO op kinds every byte account recognizes — shared
#: by this parser's `Cost.coll` breakdown, `launch/analysis.py`'s
#: roofline collective term, and the `repro.analysis.collectives`
#: inventory auditor, so the kind list can never drift between the
#: byte regression and the audit.
COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")
_COLLECTIVES = COLLECTIVE_KINDS
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id", "iota",
             "reshape"}


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    result_type: str
    op: str
    operands: list
    line: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    types: dict = field(default_factory=dict)   # name -> result type str


_OPERAND = re.compile(r"%([\w.\-]+)")
_OPCALL = re.compile(r"([a-z][a-z0-9\-]*)\(")


def parse_hlo(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur = None
    for raw in text.splitlines():
        s = raw.strip()
        if cur is None:
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", s)
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    comps["__entry__"] = cur
            continue
        if s.startswith("}"):
            cur = None
            continue
        if " = " not in s:
            continue
        lhs, rhs = s.split(" = ", 1)
        name = lhs.replace("ROOT", "").strip().lstrip("%")
        m = _OPCALL.search(rhs)
        if not m:
            continue
        rtype = rhs[:m.start()].strip()
        op = m.group(1)
        rest = rhs[m.end():]
        # operand names: inside the op's top-level parens
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        opers = _OPERAND.findall(rest[:end])
        instr = Instr(name, rtype, op, opers, s)
        cur.instrs.append(instr)
        cur.types[name] = rtype
    return comps


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_RE = re.compile(r"to=%?([\w.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_flops(instr: Instr, comp: Computation) -> float:
    res = _shape_dims(instr.result_type)
    m = _CDIMS_RE.search(instr.line)
    lhs_type = comp.types.get(instr.operands[0], "")
    lhs = _shape_dims(lhs_type)
    cdims = [int(d) for d in m.group(1).split(",") if d] if m else []
    k = 1
    for d in cdims:
        if d < len(lhs):
            k *= lhs[d]
    n = 1
    for d in res:
        n *= d
    return 2.0 * n * k


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in _COLLECTIVES}

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * mult

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def _cost_of(comp: Computation, comps: dict, cache: dict,
             inside_fusion: bool) -> Cost:
    ck = (comp.name, inside_fusion)
    if ck in cache:
        return cache[ck]
    total = Cost()
    for ins in comp.instrs:
        op = ins.op
        if op == "dot":
            total.flops += _dot_flops(ins, comp)
            if not inside_fusion:
                total.bytes += _type_bytes(ins.result_type) + sum(
                    _type_bytes(comp.types.get(o, "")) for o in ins.operands)
            continue
        if op == "fusion":
            m = _CALLS_RE.search(ins.line)
            if m and m.group(1) in comps:
                total.add(_cost_of(comps[m.group(1)], comps, cache, True))
            if not inside_fusion:
                total.bytes += _type_bytes(ins.result_type) + sum(
                    _type_bytes(comp.types.get(o, "")) for o in ins.operands)
            continue
        if op == "while":
            m = _BODY_RE.search(ins.line)
            trip = 1
            tm = _TRIP_RE.search(ins.line)
            if tm:
                trip = int(tm.group(1))
            if m and m.group(1) in comps:
                total.add(_cost_of(comps[m.group(1)], comps, cache,
                                   inside_fusion), trip)
            continue
        if op == "conditional":
            m = _BRANCHES_RE.search(ins.line)
            if m:
                branch_costs = []
                for bn in _OPERAND.findall(m.group(1)):
                    if bn in comps:
                        branch_costs.append(
                            _cost_of(comps[bn], comps, cache,
                                     inside_fusion))
                if branch_costs:
                    # max across branches (see module docstring)
                    best = max(branch_costs,
                               key=lambda c: (c.flops, c.bytes))
                    total.add(best)
            continue
        if op in ("call", "async-start"):
            m = _TO_RE.search(ins.line) or _CALLS_RE.search(ins.line)
            if m and m.group(1) in comps:
                total.add(_cost_of(comps[m.group(1)], comps, cache,
                                   inside_fusion))
            continue
        is_coll = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                is_coll = c
                break
        if is_coll:
            total.coll[is_coll] += _type_bytes(ins.result_type)
            if not inside_fusion:
                total.bytes += _type_bytes(ins.result_type)
            continue
        if op.endswith("-done") or op in _FREE_OPS:
            continue
        # generic elementwise / data movement op at top level
        if not inside_fusion:
            total.bytes += _type_bytes(ins.result_type) + sum(
                _type_bytes(comp.types.get(o, "")) for o in ins.operands)
    cache[ck] = total
    return total


def hlo_cost(text: str) -> Cost:
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")
    return _cost_of(entry, comps, {}, False)


def measure_collective_bytes(fn, *arg_structs) -> float:
    """Compile ``fn`` on ShapeDtypeStructs and count its collective
    bytes from the optimized HLO — the measurement side of every
    wire-byte regression (`tests/workers/hlo_wire_worker.py` runs this
    against each registered DP wire; the analytic side is the
    registry's `WireSpec.wire_bytes`).  jax is imported lazily so this
    module stays importable as a pure parser."""
    import jax
    text = jax.jit(fn).lower(*arg_structs).compile().as_text()
    return hlo_cost(text).coll_bytes


def entry_result_bytes(text: str) -> float:
    """Sum the byte sizes of the ENTRY computation's ROOT result — the
    buffers the compiled program hands back to the caller."""
    in_entry = False
    for raw in text.splitlines():
        s = raw.strip()
        if not in_entry:
            if re.match(r"^ENTRY\s", s):
                in_entry = True
            continue
        if s.startswith("}"):
            break
        if s.startswith("ROOT ") and " = " in s:
            rhs = s.split(" = ", 1)[1]
            m = _OPCALL.search(rhs)
            return _type_bytes(rhs[:m.start()] if m else rhs)
    raise ValueError("no ROOT instruction in ENTRY computation")


def measure_result_bytes(fn, *arg_structs) -> float:
    """Compile ``fn`` on ShapeDtypeStructs and sum its ENTRY output
    buffer bytes from the optimized HLO — the HBM-residency analogue of
    `measure_collective_bytes` for planes whose payload never crosses
    the network (z-buffer, kv-cache): the bytes the program materializes
    for the caller are what the plane's ``wire_bytes`` model claims."""
    import jax
    text = jax.jit(fn).lower(*arg_structs).compile().as_text()
    return entry_result_bytes(text)
