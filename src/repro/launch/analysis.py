"""Roofline analysis from compiled dry-run artifacts.

Three terms, per device (TPU v5e targets):

    compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16)
    memory     = HLO_bytes / HBM_bw              (819 GB/s)
    collective = collective_bytes / link_bw      (~50 GB/s/link ICI)

``cost_analysis`` supplies FLOPs and bytes; collective bytes are NOT in
cost_analysis, so we parse the post-SPMD optimized HLO and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (shapes in the partitioned module are per-device).
"""
from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass
from typing import Optional

from repro.launch.hlo_cost import COLLECTIVE_KINDS

# -- TPU v5e hardware constants (per chip) ----------------------------------
PEAK_FLOPS = 197e12            # bf16
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# one shared kind list (launch/hlo_cost.py) — the roofline breakdown,
# the byte regression and the repro.analysis auditor cannot drift
_COLLECTIVE_KINDS = COLLECTIVE_KINDS


def _shape_bytes(type_str: str) -> int:
    """'bf16[8,128,4096]' -> bytes; tuples handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += int(n * _DTYPE_BYTES[dtype])
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVE_KINDS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # format: %name = TYPE kind(operands...), ...
        m = re.match(r"%?[\w.\-]+ = (.*?) (\w[\w\-]*)\(", s)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        kind = None
        for k in _COLLECTIVE_KINDS:
            if op == k or op.startswith(k + "-"):  # e.g. all-gather-start
                kind = k
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue                       # avoid double-counting async pairs
        # payload ~ result size for gather-style; operand size for others —
        # use the max of result and first-operand bytes as the wire payload.
        res_b = _shape_bytes(result_type)
        out[kind] += res_b
        out["count"] += 1
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: dict
    memory_per_device: float           # HBM footprint (args+temps)
    model_flops: float                 # analytic 6·N·D (global)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0

    def finalize(self):
        self.compute_s = self.flops_per_device / PEAK_FLOPS
        self.memory_s = self.bytes_per_device / HBM_BW
        self.collective_s = self.coll_bytes_per_device / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        total_flops = self.flops_per_device * self.chips
        self.useful_ratio = (self.model_flops / total_flops
                             if total_flops else 0.0)
        return self

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_desc: str,
                     chips: int, model_flops: float) -> Roofline:
    """Roofline terms via the loop-aware HLO parser (hlo_cost); XLA's own
    cost_analysis is kept as `xla_*` cross-check fields (it counts while
    bodies once, so it underestimates scanned programs)."""
    from repro.launch import hlo_cost as H
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    cost = H.hlo_cost(hlo)
    mem = (getattr(ma, "argument_size_in_bytes", 0)
           + getattr(ma, "temp_size_in_bytes", 0)
           + getattr(ma, "output_size_in_bytes", 0))
    breakdown = {k: int(v) for k, v in cost.coll.items()}
    breakdown["xla_flops"] = float(ca.get("flops", 0.0))
    breakdown["xla_bytes"] = float(ca.get("bytes accessed", 0.0))
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        flops_per_device=float(cost.flops),
        bytes_per_device=float(cost.bytes),
        coll_bytes_per_device=float(cost.coll_bytes),
        coll_breakdown=breakdown,
        memory_per_device=float(mem),
        model_flops=float(model_flops),
    ).finalize()


def model_flops_estimate(cfg, shape_kind: str, global_batch: int,
                         seq_len: int) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D for
    inference (D = processed tokens)."""
    n_active = cfg.active_params_count()
    if shape_kind == "train":
        tokens = global_batch * seq_len
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        tokens = global_batch * seq_len
        return 2.0 * n_active * tokens
    tokens = global_batch * 1          # decode: one token per sequence
    return 2.0 * n_active * tokens


def save_results(path: str, rows: list):
    with open(path, "w") as f:
        json.dump([r.to_dict() if isinstance(r, Roofline) else r
                   for r in rows], f, indent=1)


def load_results(path: str) -> list:
    with open(path) as f:
        return json.load(f)
