"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax device query.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - older jax: Auto is the default
    AxisType = None

try:  # jax >= 0.5
    from jax import shard_map as _shard_map
    _SM_KW = {"check_vma": False}
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_KW = {"check_rep": False}


def _mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh_auto(shape, axes):
    """Version-portable mesh with all axes in Auto (collective) mode."""
    return _mesh(shape, axes)


def shard_map(f, mesh, in_specs, out_specs):
    """Version-portable jax.shard_map with replication checking off."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **_SM_KW)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for in-container multi-device tests (8 host devices)."""
    return _mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes that carry data parallelism ('pod' folds into data)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name) -> int:
    return mesh.shape[name]
