"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax device query.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def _mesh(shape, axes):
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for in-container multi-device tests (8 host devices)."""
    return _mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes that carry data parallelism ('pod' folds into data)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name) -> int:
    return mesh.shape[name]
