"""Training launcher.

Single-host execution drives the bit-faithful simulated pipeline (the
science path); passing --distributed uses the shard_map GPipe pipeline on
whatever devices exist (set XLA_FLAGS=--xla_force_host_platform_device_count=N
for CPU experiments; on TPU pods it runs as-is).

Examples:
  python -m repro.launch.train --arch gpt2-xl-paper --smoke \\
      --mode aqsgd --fw-bits 4 --bw-bits 8 --steps 100
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  python -m repro.launch.train --arch gemma2-9b --smoke --distributed \\
      --data-par 4 --stages 2 --steps 10
"""
from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-xl-paper")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--mode", default="aqsgd",
                    choices=["fp32", "directq", "aqsgd"])
    ap.add_argument("--fw-bits", type=int, default=4)
    ap.add_argument("--bw-bits", type=int, default=8)
    ap.add_argument("--buffer-bits", type=int, default=0)
    ap.add_argument("--dp-grad-bits", type=int, default=0,
                    help="b-bit error-feedback gradient compression on "
                         "the DP axis (0 = off; Fig. 5 end-to-end mode)")
    ap.add_argument("--dp-workers", type=int, default=2,
                    help="simulated DP degree for --dp-grad-bits in the "
                         "single-host trainer")
    ap.add_argument("--dp-wire", default="ring",
                    choices=["ring", "psum", "ring-sharded"],
                    help="DP gradient collective (--distributed only): "
                         "ring ships the packed b-bit codes themselves "
                         "(bandwidth-optimal); psum is the conservative "
                         "i32-lane collective; ring-sharded is the ZeRO "
                         "wire (reduce-scatter half only, segment-owner "
                         "optimizer).  All three produce bit-identical "
                         "gradient values (ring==psum losses are "
                         "bit-equal; ring-sharded losses track at ulp "
                         "level — its optimizer compiles differently)")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--samples", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup-epochs", type=int, default=1)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--data-par", type=int, default=2)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--corpus", default="",
                    help="optional text file to train on (byte-level)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_config
    from repro.core.aqsgd import CompressionConfig
    from repro.data.pipeline import Dataset, DatasetConfig
    from repro.optim.adamw import AdamWConfig
    from repro.checkpoint import checkpoint as ckpt

    cfg = get_config(args.arch, smoke=args.smoke)
    cc = CompressionConfig(mode=args.mode, fw_bits=args.fw_bits,
                           bw_bits=args.bw_bits,
                           buffer_bits=args.buffer_bits)
    dc = DatasetConfig(num_samples=args.samples, seq_len=args.seq,
                       vocab_size=cfg.vocab_size,
                       kind="textfile" if args.corpus else "synthetic-lm",
                       path=args.corpus or None)
    ds = Dataset(dc)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                      total_steps=args.steps)

    if not args.distributed:
        from repro.training import simulated as sim
        tcfg = sim.SimTrainConfig(num_stages=args.stages, compression=cc,
                                  optimizer=opt,
                                  dp_grad_bits=args.dp_grad_bits,
                                  dp_workers=args.dp_workers
                                  if args.dp_grad_bits else 1)
        state, losses = sim.train(cfg, tcfg, ds, num_steps=args.steps,
                                  batch_size=args.batch, log_every=10)
        print(f"final loss {np.mean(losses[-5:]):.4f}")
        if args.checkpoint:
            ckpt.save(args.checkpoint, state["params"])
            print("saved", args.checkpoint)
        return

    # ---- distributed shard_map pipeline ------------------------------------
    from repro.launch.mesh import make_debug_mesh
    from repro.models import model as Mo
    from repro.optim import adamw
    from repro.training import pipeline as PL

    mesh = make_debug_mesh(args.data_par, args.stages)
    pcfg = PL.PipelineConfig(microbatches=args.microbatches,
                             compression=cc, warmup=True,
                             dp_grad_bits=args.dp_grad_bits,
                             dp_wire=args.dp_wire)
    gb = args.batch
    step_w, meta = PL.make_train_step(cfg, pcfg, mesh, opt,
                                      global_batch=gb, seq_len=args.seq,
                                      buffer_samples=args.samples
                                      // args.data_par)
    pcfg2 = PL.PipelineConfig(microbatches=args.microbatches,
                              compression=cc, warmup=False,
                              dp_grad_bits=args.dp_grad_bits,
                              dp_wire=args.dp_wire)
    step_c, _ = PL.make_train_step(cfg, pcfg2, mesh, opt,
                                   global_batch=gb, seq_len=args.seq,
                                   buffer_samples=args.samples
                                   // args.data_par)
    params = PL.to_pipeline_params(
        cfg, Mo.init_params(cfg, jax.random.PRNGKey(0)), args.stages)
    if args.dp_grad_bits and args.dp_wire == "ring-sharded":
        opt_state = PL.init_sharded_opt(pcfg, params, args.data_par)
    else:
        opt_state = adamw.init_opt_state(params)
    state = {"params": params, "opt": opt_state}
    if args.dp_grad_bits:
        state["dp_error"] = PL.init_dp_error(pcfg, params, args.data_par)
    if cc.mode == "aqsgd":
        n_loc = args.samples // args.data_par
        bshape = (args.stages, args.data_par * n_loc, args.seq, cfg.d_model)
        state["m_out"] = jnp.zeros(bshape, jnp.bfloat16)
        state["m_in"] = jnp.zeros(bshape, jnp.bfloat16)

    m = args.microbatches
    steps_per_epoch = max(args.samples // gb, 1)
    key = jax.random.PRNGKey(1)
    for step_i, batch in enumerate(ds.batches(gb, args.steps)):
        batch = {k: jnp.asarray(v).reshape(m, gb // m, *v.shape[1:])
                 for k, v in batch.items()}
        fn = step_w if (cc.mode == "aqsgd"
                        and step_i < steps_per_epoch
                        * args.warmup_epochs) else step_c
        state, metrics = fn(state, batch, jax.random.fold_in(key, step_i))
        if step_i % 10 == 0:
            print(f"step {step_i:5d} loss {float(metrics['loss']):.4f}")
    print(f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
