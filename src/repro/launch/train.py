"""Training launcher.

Single-host execution drives the bit-faithful simulated pipeline (the
science path); passing --distributed uses the shard_map GPipe pipeline on
whatever devices exist (set XLA_FLAGS=--xla_force_host_platform_device_count=N
for CPU experiments; on TPU pods it runs as-is).

All communication knobs are one `repro.comm.CommConfig`: the flat flags
below (--mode/--fw-bits/--bw-bits/--buffer-bits/--dp-grad-bits/
--dp-wire/...) build it, or pass the whole thing as JSON with
--comm-config (a literal string or a path).  --dp-wire choices and
their help one-liners come from the wire registry; --list-wires prints
the full registry table (every plane, every wire, its byte model).

Examples:
  python -m repro.launch.train --arch gpt2-xl-paper --smoke \\
      --mode aqsgd --fw-bits 4 --bw-bits 8 --steps 100
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  python -m repro.launch.train --arch gemma2-9b --smoke --distributed \\
      --data-par 4 --stages 2 --steps 10
  python -m repro.launch.train --smoke --steps 10 \\
      --comm-config '{"mode": "aqsgd", "dp": {"bits": 4, "wire": "fp16"}}'
"""
from __future__ import annotations

import argparse

import numpy as np


def print_wires() -> None:
    """The --list-wires table: every registered wire, from the
    registry metadata (the same source the --dp-wire help uses)."""
    from repro.comm import list_wires
    rows = [(s.plane, s.name,
             ("sharded" if s.sharded else "") +
             ("" if s.network else "local"),
             s.summary) for s in list_wires()]
    wp = max(len(r[0]) for r in rows)
    wn = max(len(r[1]) for r in rows)
    wf = max(len(r[2]) for r in rows)
    print(f"{'plane':{wp}}  {'wire':{wn}}  {'':{wf}}  summary")
    for p, n, f, s in rows:
        print(f"{p:{wp}}  {n:{wn}}  {f:{wf}}  {s}")


def main():
    from repro.comm import config as comm_cli

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-xl-paper")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    comm_cli.add_cli_args(ap)
    ap.add_argument("--list-wires", action="store_true",
                    help="print the wire registry table and exit")
    ap.add_argument("--dp-workers", type=int, default=2,
                    help="simulated DP degree for --dp-grad-bits in the "
                         "single-host trainer")
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--samples", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup-epochs", type=int, default=1)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--data-par", type=int, default=2)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--checkpoint", default="",
                    help="legacy params-only .npz export at exit "
                         "(full-state checkpointing is --ckpt-dir)")
    ap.add_argument("--corpus", default="",
                    help="optional text file to train on (byte-level)")
    ap.add_argument("--ckpt-dir", default="",
                    help="versioned full-state checkpoint directory "
                         "(repro.checkpoint manifest subsystem)")
    ap.add_argument("--save-every", type=int, default=0,
                    help="checkpoint the FULL train state every N "
                         "steps (0 = off; needs --ckpt-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest committed checkpoint "
                         "in --ckpt-dir (checksums, structure and "
                         "comm config are verified; the replayed loss "
                         "stream is bit-identical)")
    ap.add_argument("--keep", type=int, default=3,
                    help="keep-last-k checkpoint rotation (0 = keep "
                         "all)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="bounded fault recovery: reload the last "
                         "good checkpoint and replay at most this "
                         "many times")
    ap.add_argument("--fault", default="",
                    help="deterministic fault injection plan, "
                         "step:plane:kind[,...] — e.g. "
                         "'3:dp:nan-scale,5:fw:drop-hop' (kinds: "
                         "corrupt-codes, nan-scale, drop-hop; "
                         "single-host trainer only)")
    ap.add_argument("--kill-at", type=int, default=None,
                    help="hard-exit (os._exit 17) right after "
                         "printing step N's loss, before any save — "
                         "the kill half of the kill-and-resume parity "
                         "gate (single-host trainer only)")
    args = ap.parse_args()

    if args.list_wires:
        print_wires()
        return

    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_config
    from repro.data.pipeline import Dataset, DatasetConfig
    from repro.optim.adamw import AdamWConfig
    from repro.checkpoint import checkpoint as ckpt

    comm = comm_cli.from_args(args)
    cfg = get_config(args.arch, smoke=args.smoke)
    dc = DatasetConfig(num_samples=args.samples, seq_len=args.seq,
                       vocab_size=cfg.vocab_size,
                       kind="textfile" if args.corpus else "synthetic-lm",
                       path=args.corpus or None)
    ds = Dataset(dc)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                      total_steps=args.steps)

    if args.fault and args.distributed:
        ap.error("--fault targets the single-host simulated trainer")
    if args.kill_at is not None and args.distributed:
        ap.error("--kill-at targets the single-host simulated trainer")
    if (args.resume or args.save_every or args.fault) \
            and not args.ckpt_dir:
        ap.error("--resume/--save-every/--fault need --ckpt-dir")

    if not args.distributed:
        from repro.comm.faults import FaultPlan
        from repro.launch import runner
        from repro.training import simulated as sim
        tcfg = sim.SimTrainConfig(num_stages=args.stages, comm=comm,
                                  optimizer=opt,
                                  dp_workers=args.dp_workers
                                  if comm.dp.bits else 1)
        state, losses = runner.run_sim_training(
            cfg, tcfg, ds, num_steps=args.steps,
            batch_size=args.batch, log_every=10,
            ckpt_dir=args.ckpt_dir, save_every=args.save_every,
            keep=args.keep, resume=args.resume,
            max_retries=args.max_retries,
            fault_plan=FaultPlan.parse(args.fault),
            kill_at=args.kill_at)
        print(f"final loss {np.mean(losses[-5:]):.4f}")
        if args.checkpoint:
            ckpt.save(args.checkpoint, state["params"])
            print("saved", args.checkpoint)
        return

    # ---- distributed shard_map pipeline ------------------------------------
    from repro.launch.mesh import make_debug_mesh
    from repro.models import model as Mo
    from repro.optim import adamw
    from repro.training import pipeline as PL

    mesh = make_debug_mesh(args.data_par, args.stages)
    pcfg = PL.PipelineConfig(microbatches=args.microbatches,
                             comm=comm, warmup=True)
    gb = args.batch
    step_w, meta = PL.make_train_step(cfg, pcfg, mesh, opt,
                                      global_batch=gb, seq_len=args.seq,
                                      buffer_samples=args.samples
                                      // args.data_par)
    pcfg2 = PL.PipelineConfig(microbatches=args.microbatches,
                              comm=comm, warmup=False)
    step_c, _ = PL.make_train_step(cfg, pcfg2, mesh, opt,
                                   global_batch=gb, seq_len=args.seq,
                                   buffer_samples=args.samples
                                   // args.data_par)
    params = PL.to_pipeline_params(
        cfg, Mo.init_params(cfg, jax.random.PRNGKey(0)), args.stages)
    if comm.dp.bits and comm.dp_wire_spec.sharded:
        opt_state = PL.init_sharded_opt(pcfg, params, args.data_par)
    else:
        opt_state = adamw.init_opt_state(params)
    state = {"params": params, "opt": opt_state}
    if comm.dp.bits:
        state["dp_error"] = PL.init_dp_error(pcfg, params, args.data_par)
    if comm.mode == "aqsgd":
        n_loc = args.samples // args.data_par
        structs = PL.buffer_structs(pcfg, args.stages,
                                    args.data_par * n_loc, args.seq,
                                    cfg.d_model)
        zeros = lambda s: jnp.zeros(s.shape, s.dtype)
        state["m_out"] = jax.tree.map(zeros, structs)
        state["m_in"] = jax.tree.map(zeros, structs)

    start = 0
    if args.ckpt_dir:
        removed = ckpt.clean_orphans(args.ckpt_dir)
        if removed:
            print(f"checkpoint: removed {len(removed)} orphaned tmp "
                  f"entries")
    if args.resume:
        state, body = ckpt.restore_state(args.ckpt_dir,
                                         jax.eval_shape(lambda: state),
                                         comm=comm)
        start = int(body["step"])
        print(f"resumed from step {start}")

    m = args.microbatches
    steps_per_epoch = max(args.samples // gb, 1)
    key = jax.random.PRNGKey(1)
    batches = ds.batches(gb, args.steps)
    for _ in range(start):
        next(batches)   # the data stream is deterministic: replay by
                        # skipping to the checkpointed position
    metrics = None
    for step_i, batch in enumerate(batches, start=start):
        batch = {k: jnp.asarray(v).reshape(m, gb // m, *v.shape[1:])
                 for k, v in batch.items()}
        fn = step_w if (comm.mode == "aqsgd"
                        and step_i < steps_per_epoch
                        * args.warmup_epochs) else step_c
        state, metrics = fn(state, batch, jax.random.fold_in(key, step_i))
        if step_i % 10 == 0:
            loss = float(metrics["loss"])
            print(f"step {step_i:5d} loss {loss:.4f} [{loss.hex()}]")
        done = step_i + 1
        if args.ckpt_dir and args.save_every \
                and done % args.save_every == 0:
            ckpt.save_state(args.ckpt_dir, state, step=done, comm=comm,
                            extra={"data_position": done},
                            keep=args.keep)
    if metrics is not None:
        print(f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
