"""Serving launcher: batched prefill + decode with the pjit-sharded
serve step (reduced configs run on host devices; full configs are the
dry-run's domain).

Example:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  python -m repro.launch.serve --arch gemma2-9b --smoke --batch 8 \\
      --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.models import model as Mo
    from repro.serving import decode as Sv

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_debug_mesh(args.data_par, args.model_par)
    key = jax.random.PRNGKey(0)
    params = Mo.init_params(cfg, key)
    cache_len = args.prompt_len + args.gen + (cfg.num_patches or 0)
    caches = Mo.init_caches(cfg, args.batch, cache_len, jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = jax.random.normal(
            key, (args.batch, cfg.num_patches, cfg.d_model)) * 0.02
    if cfg.family == "audio":
        extras["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model)) * 0.02

    with mesh:
        t0 = time.time()
        logits, caches = Mo.forward_with_caches(
            params, cfg, tokens, caches, logits_last_only=True, **extras)
        logits.block_until_ready()
        t1 = time.time()
        print(f"prefill {args.batch}x{args.prompt_len}: {t1-t0:.2f}s")

        step = jax.jit(lambda p, c, t: Mo.forward_with_caches(
            p, cfg, t, c, logits_last_only=True))
        out_tokens = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        for i in range(args.gen):
            out_tokens.append(tok)
            logits, caches = step(params, caches, tok)
            if args.temperature > 0:
                tok = jax.random.categorical(
                    jax.random.fold_in(key, i),
                    logits[:, -1] / args.temperature)[:, None]
            else:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        jax.block_until_ready(tok)
        t2 = time.time()
        gen = jnp.concatenate(out_tokens, axis=1)
        print(f"decode {args.gen} tokens: {t2-t1:.2f}s "
              f"({args.gen*args.batch/(t2-t1):.1f} tok/s)")
        print("sample token ids:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
