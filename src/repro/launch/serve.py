"""Serving launcher: the train CLI's comm surface pointed at decode.

Batched prefill + decode (reduced configs run on host devices; full
configs are the dry-run's domain) with the compressed serving plane:
``--kv-bits`` switches the KV cache to packed codes + group scales,
``--stages N`` routes the hidden state through N-1 delta-coded pipeline
hops per token (`serving.delta`), and ``--continuous`` drives a
mixed-length request stream through the paged `serving.batcher`.

Communication knobs are ONE `repro.comm.CommConfig` — the same flags
(--mode/--fw-bits/--kv-bits/...) and ``--comm-config`` JSON as
`repro.launch.train`, and the resolved config is echoed back as JSON
(the round-trip surface).  ``--list-wires`` prints the same registry
table, serving planes included.

Examples:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  python -m repro.launch.serve --arch gemma2-9b --smoke --batch 8 \\
      --prompt-len 32 --gen 16 --kv-bits 8
  python -m repro.launch.serve --smoke --stages 2 --mode aqsgd \\
      --fw-bits 4 --gen 12
  python -m repro.launch.serve --smoke --continuous --slots 4 --gen 8 \\
      --comm-config '{"mode": "aqsgd", "kv": {"bits": 8}}'
"""
from __future__ import annotations

import argparse
import time


def main():
    from repro.comm import config as comm_cli
    from repro.launch.train import print_wires

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--smoke", action="store_true")
    comm_cli.add_cli_args(ap)
    ap.add_argument("--list-wires", action="store_true",
                    help="print the wire registry table and exit")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--stages", type=int, default=1,
                    help="pipeline stage groups for decode; >1 routes "
                         "the hidden state through delta-coded hops")
    ap.add_argument("--continuous", action="store_true",
                    help="serve a mixed-length request stream through "
                         "the continuous batcher instead of one "
                         "uniform batch")
    ap.add_argument("--slots", type=int, default=0,
                    help="batcher cache slots (default: --batch)")
    args = ap.parse_args()

    if args.list_wires:
        print_wires()
        return

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.base import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.models import model as Mo
    from repro.serving import (ContinuousBatcher, DeltaHopCodec, KVCodec,
                               quantize_caches)

    comm = comm_cli.from_args(args)
    print("comm:", comm.to_json())
    cfg = get_config(args.arch, smoke=args.smoke)
    kv_codec = KVCodec.from_comm(comm)
    hop = DeltaHopCodec.from_comm(comm) if args.stages > 1 else None
    if hop is not None:
        per_hop = hop.hop_bytes(args.batch, cfg.d_model)
        raw_hop = args.batch * cfg.d_model * 4
        print(f"decode hop [{comm.mode}]: {per_hop} B/token/boundary "
              f"x {args.stages - 1} boundaries (fp32 {raw_hop} B)")
    if kv_codec.bits:
        per_tok = kv_codec.stored_bytes(
            (1, 1, cfg.num_kv_heads, cfg.head_dim)) * 2 * cfg.num_layers
        raw_tok = 2 * cfg.num_layers * cfg.num_kv_heads * cfg.head_dim * 4
        print(f"kv cache: {per_tok} B/token stored "
              f"({kv_codec.bits}-bit; raw f32 {raw_tok} B)")

    key = jax.random.PRNGKey(0)
    params = Mo.init_params(cfg, key)
    cache_len = args.prompt_len + args.gen + (cfg.num_patches or 0)

    if args.continuous:
        slots = args.slots or args.batch
        bat = ContinuousBatcher(
            params, cfg, num_slots=slots, cache_len=cache_len,
            kv_codec=kv_codec, hop_codec=hop, num_stages=args.stages)
        rng = np.random.default_rng(1)
        t0 = time.time()
        for r in range(args.batch * 2):   # oversubscribe: forces evict+admit
            plen = int(rng.integers(4, args.prompt_len + 1))
            bat.submit(rng.integers(0, cfg.vocab_size, plen).tolist(),
                       max_new_tokens=args.gen)
        reqs = bat.run()
        dt = time.time() - t0
        n_tok = sum(len(r.tokens) for r in reqs)
        print(f"continuous: {len(reqs)} requests over {slots} slots, "
              f"{n_tok} tokens in {dt:.1f}s ({n_tok/dt:.1f} tok/s)")
        for r in reqs[:4]:
            print(f"  prompt[{len(r.prompt):3d}] -> {r.tokens[:8]}")
        return

    mesh = make_debug_mesh(args.data_par, args.model_par)
    caches = Mo.init_caches(cfg, args.batch, cache_len, jnp.float32)
    if kv_codec.bits:
        caches = quantize_caches(cfg, caches, kv_codec)
    if hop is not None:
        caches["hop_m"] = hop.init_state(args.stages - 1, args.batch,
                                         cfg.d_model)["m"]
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = jax.random.normal(
            key, (args.batch, cfg.num_patches, cfg.d_model)) * 0.02
    if cfg.family == "audio":
        extras["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model)) * 0.02

    kvc = kv_codec if kv_codec.bits else None
    bfn_p = hop.boundary_fn(prefill=True) if hop is not None else None
    bfn_d = hop.boundary_fn(prefill=False) if hop is not None else None
    with mesh:
        t0 = time.time()
        logits, caches = Mo.forward_with_caches(
            params, cfg, tokens, caches, logits_last_only=True,
            num_stages=args.stages, boundary_fn=bfn_p, kv_codec=kvc,
            **extras)
        logits.block_until_ready()
        t1 = time.time()
        print(f"prefill {args.batch}x{args.prompt_len}: {t1-t0:.2f}s")

        step = jax.jit(lambda p, c, t: Mo.forward_with_caches(
            p, cfg, t, c, logits_last_only=True, num_stages=args.stages,
            boundary_fn=bfn_d, kv_codec=kvc))
        out_tokens = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        for i in range(args.gen):
            out_tokens.append(tok)
            logits, caches = step(params, caches, tok)
            if args.temperature > 0:
                tok = jax.random.categorical(
                    jax.random.fold_in(key, i),
                    logits[:, -1] / args.temperature)[:, None]
            else:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        jax.block_until_ready(tok)
        t2 = time.time()
        gen = jnp.concatenate(out_tokens, axis=1)
        print(f"decode {args.gen} tokens: {t2-t1:.2f}s "
              f"({args.gen*args.batch/(t2-t1):.1f} tok/s)")
        print("sample token ids:", gen[0][:12].tolist())


if __name__ == "__main__":
    main()
