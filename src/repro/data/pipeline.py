"""Data pipeline.

AQ-SGD keys its message buffers on *sample identity across epochs*, so —
unlike an ordinary LM data loader — every batch carries stable
``sample_ids``.  The paper (§3.3) also notes shuffling less often reduces
DP buffer movement; we expose ``shuffle_each_epoch``.

Two corpus sources (offline container — no HF downloads, DESIGN.md §7):
* synthetic Zipf-distributed token sequences with injected n-gram
  structure (so models can actually learn and loss curves are meaningful);
* byte/token-level encoding of any local text file.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class DatasetConfig:
    num_samples: int = 256
    seq_len: int = 128
    vocab_size: int = 512
    kind: str = "synthetic-lm"      # synthetic-lm | textfile
    path: Optional[str] = None
    seed: int = 0
    shuffle_each_epoch: bool = True


def _synthetic_corpus(dc: DatasetConfig) -> np.ndarray:
    """Zipf tokens with planted bigram transitions: predictable enough
    that fine-tuning has signal, noisy enough that loss stays > 0."""
    rng = np.random.default_rng(dc.seed)
    v = dc.vocab_size
    # planted deterministic successor table for 60% of transitions
    succ = rng.integers(0, v, size=v)
    zipf_p = 1.0 / np.arange(1, v + 1)
    zipf_p /= zipf_p.sum()
    toks = np.empty((dc.num_samples, dc.seq_len + 1), np.int32)
    for i in range(dc.num_samples):
        seq = np.empty(dc.seq_len + 1, np.int32)
        seq[0] = rng.integers(0, v)
        rand = rng.random(dc.seq_len)
        draws = rng.choice(v, size=dc.seq_len, p=zipf_p)
        for t in range(1, dc.seq_len + 1):
            seq[t] = succ[seq[t - 1]] if rand[t - 1] < 0.6 else draws[t - 1]
        toks[i] = seq
    return toks


def _textfile_corpus(dc: DatasetConfig) -> np.ndarray:
    raw = np.frombuffer(open(dc.path, "rb").read(), np.uint8)
    raw = raw.astype(np.int32) % dc.vocab_size
    need = dc.num_samples * (dc.seq_len + 1)
    reps = -(-need // raw.size)
    raw = np.tile(raw, reps)[:need]
    return raw.reshape(dc.num_samples, dc.seq_len + 1)


class Dataset:
    """Epoch iterator yielding dict batches with stable sample ids."""

    def __init__(self, dc: DatasetConfig):
        self.dc = dc
        if dc.kind == "synthetic-lm":
            self.tokens = _synthetic_corpus(dc)
        elif dc.kind == "textfile":
            self.tokens = _textfile_corpus(dc)
        else:
            raise ValueError(dc.kind)
        self.reset()

    def reset(self) -> None:
        """Rewind the (mutable) shuffle state to step 0: the stream is
        then a pure function of the config seed again.  Replay-based
        resume (`launch.runner`) depends on this — `epoch` advances
        ``self.rng`` in place, so re-calling `batches` WITHOUT a reset
        yields a different (continued-rng) stream."""
        self.rng = np.random.default_rng(self.dc.seed + 1)
        self._order = np.arange(self.dc.num_samples)

    @property
    def num_samples(self) -> int:
        return self.dc.num_samples

    def epoch(self, batch_size: int, shuffle: Optional[bool] = None
              ) -> Iterator[dict]:
        if shuffle is None:
            shuffle = self.dc.shuffle_each_epoch
        if shuffle:
            self.rng.shuffle(self._order)
        n = (self.dc.num_samples // batch_size) * batch_size
        for i in range(0, n, batch_size):
            ids = self._order[i:i + batch_size]
            chunk = self.tokens[ids]
            yield {
                "tokens": chunk[:, :-1],
                "targets": chunk[:, 1:],
                "mask": np.ones((batch_size, self.dc.seq_len), np.float32),
                "sample_ids": ids.astype(np.int32),
            }

    def batches(self, batch_size: int, num_steps: int) -> Iterator[dict]:
        done = 0
        while done < num_steps:
            for b in self.epoch(batch_size):
                yield b
                done += 1
                if done >= num_steps:
                    return
