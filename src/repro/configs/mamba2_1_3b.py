"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060].  48L, d_model=2048, ssm_state=128, headdim=64,
expand=2, vocab=50280.  No FFN — the Mamba2 block is the whole layer.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, vocab_size=512, ssm_state=16, ssm_headdim=32,
    ssm_chunk=32,
)
