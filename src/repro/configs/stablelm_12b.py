"""stablelm-12b [dense] — plain GQA dense decoder.

[hf:stabilityai/stablelm-2-1_6b (family)].  40L, d_model=5120, 32H
(GQA kv=8), d_ff=13824, vocab=100352.  Closest assigned arch to the
paper's own GPT2-XL setting.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    rope_theta=10_000.0,
    act="silu",
    tie_embeddings=False,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512,
)
