"""whisper-small [audio] — encoder-decoder transformer backbone.

[arXiv:2212.04356].  12L enc + 12L dec, d_model=768, 12H, d_ff=3072,
vocab=51865.  The mel-spectrogram + conv frontend is a STUB per the
assignment: ``input_specs()`` supplies 1500 precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=12,                  # decoder layers
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    encoder_layers=12,
    encoder_seq=1500,               # 30 s of audio at 50 Hz (conv stub)
    cross_attention=True,
    act="gelu",
    mlp_gated=False,
    tie_embeddings=True,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=512, encoder_layers=2, encoder_seq=32,
)
