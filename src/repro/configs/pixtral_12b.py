"""pixtral-12b [vlm] — Pixtral-ViT + Mistral-Nemo decoder backbone.

[hf:mistralai/Pixtral-12B-2409].  The vision frontend is a STUB per the
assignment: ``input_specs()`` feeds precomputed patch embeddings for the
leading ``num_patches`` positions; we build the language decoder that
consumes them (40L, d_model=5120, 32H GQA kv=8, d_ff=14336, v=131072).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    source="hf:mistralai/Pixtral-12B-2409",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000_000.0,
    num_patches=1024,               # stubbed ViT patch embeddings
    tie_embeddings=False,
    act="silu",
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512, num_patches=16,
)
