"""zamba2-2.7b [hybrid] — Mamba2 trunk + shared-weight attention blocks.

[arXiv:2411.15242].  54L, d_model=2560, ssm_state=64; one shared
attention+FFN block (32H, GQA kv=32, d_ff=10240) is invoked every 6th
layer, reusing the same weights each time (Zamba design).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,                     # shared block FFN
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    shared_attn_every=6,
)

SMOKE = CONFIG.with_(
    num_layers=4, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=512, ssm_state=16, ssm_headdim=32, ssm_chunk=32,
    shared_attn_every=2,
)
