"""deepseek-moe-16b [moe] — fine-grained MoE, 2 shared + 64 routed top-6.

[arXiv:2401.06066].  28L, d_model=2048, 16H (GQA kv=16), expert d_ff=1408,
vocab=102400; the first layer keeps a dense FFN (paper's design).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="arXiv:2401.06066",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,                     # dense FFN width of the first layer
    vocab_size=102400,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=10_000.0,
    act="silu",
)

SMOKE = CONFIG.with_(
    capacity_factor=8.0,   # no-drop in smoke tests (determinism)
    num_layers=3, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=512, n_experts=4, top_k=2, n_shared_experts=1,
    moe_d_ff=128, first_dense_layers=1,
)
