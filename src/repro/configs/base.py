"""Architecture config schema + registry.

Every assigned architecture gets one ``configs/<id>.py`` defining a
``CONFIG`` (the exact full-scale config from the assignment sheet, source
cited) and a ``SMOKE`` reduced variant (<=2 layers, d_model<=512,
<=4 experts) exercised by the CPU smoke tests.  The full configs are only
ever lowered via ShapeDtypeStruct in the dry-run — never allocated.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    source: str                     # citation from the assignment sheet
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # --- attention details -------------------------------------------------
    rope_theta: float = 10_000.0
    sliding_window: int = 0         # 0 = no local attention anywhere
    local_global_period: int = 0    # gemma2: 2 -> alternate local/global
    attn_softcap: float = 0.0       # gemma2 logit soft-capping
    final_softcap: float = 0.0
    attn_every: int = 1             # hybrid: attention layers cadence (0=never)

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0               # routed-expert hidden size
    first_dense_layers: int = 0     # deepseek-moe: leading dense FFN layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM (Mamba2 / SSD) ---------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_groups: int = 1

    # --- hybrid (zamba2) --------------------------------------------------
    shared_attn_every: int = 0      # shared-weight attention block cadence

    # --- encoder/decoder (whisper) ---------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0            # precomputed frame embeddings (stub)
    cross_attention: bool = False

    # --- multimodal stub (pixtral) ----------------------------------------
    num_patches: int = 0            # leading positions fed by patch embeds

    # --- misc --------------------------------------------------------------
    act: str = "silu"               # silu (SwiGLU) | gelu
    mlp_gated: bool = True          # gated (3-matrix) FFN vs plain 2-matrix
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "float32"          # runtime compute dtype

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived -------------------------------------------------------------
    @property
    def jax_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def layer_is_local(self, i: int) -> bool:
        """Sliding-window (local) attention at layer i?"""
        if self.sliding_window == 0:
            return False
        if self.local_global_period:
            return i % self.local_global_period == 0
        return True                  # mixtral: SWA everywhere

    def layer_window(self, i: int, seq_len: int) -> int:
        return self.sliding_window if self.layer_is_local(i) else seq_len

    def layer_is_mamba(self, i: int) -> bool:
        return self.family in ("ssm", "hybrid")

    def layer_has_shared_attn(self, i: int) -> bool:
        if not self.shared_attn_every:
            return False
        return i % self.shared_attn_every == self.shared_attn_every - 1

    def layer_is_moe(self, i: int) -> bool:
        return self.has_moe and i >= self.first_dense_layers

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def params_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d                      # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        per_attn = (self.num_heads * self.head_dim * d      # wq
                    + 2 * self.num_kv_heads * self.head_dim * d  # wk, wv
                    + self.num_heads * self.head_dim * d)   # wo
        per_dense_ffn = (3 if self.mlp_gated else 2) * d * self.d_ff
        for i in range(L):
            if self.layer_is_mamba(i):
                di, hs = self.d_inner, self.ssm_heads
                conv_dim = di + 2 * self.ssm_groups * self.ssm_state
                n += d * (2 * di + 2 * self.ssm_groups * self.ssm_state + hs)
                n += conv_dim * self.ssm_conv_width
                n += 2 * hs + di                    # A_log, D, gated-norm
                n += di * d                          # out_proj
            else:
                n += per_attn
            if self.family in ("ssm",):
                pass                                 # mamba2 has no FFN
            elif self.family == "hybrid":
                pass                                 # zamba2 trunk: mamba only
            elif self.layer_is_moe(i):
                n += 3 * d * self.moe_d_ff * self.n_experts
                n += 3 * d * self.moe_d_ff * self.n_shared_experts
                n += d * self.n_experts              # router
            else:
                n += per_dense_ffn
            n += 2 * d                               # 2 norms
        if self.shared_attn_every:                   # zamba2 shared block
            n += per_attn + per_dense_ffn + 2 * d
        if self.encoder_layers:                      # whisper encoder
            n += self.encoder_layers * (per_attn + per_dense_ffn + 2 * d)
            n += L * (per_attn + d)                  # decoder cross-attn
        n += d                                       # final norm
        return n

    def active_params_count(self) -> int:
        """Active params per token (MoE: top_k + shared only)."""
        if not self.has_moe:
            return self.params_count()
        full = self.params_count()
        L_moe = self.num_layers - self.first_dense_layers
        inactive = 3 * self.d_model * self.moe_d_ff * \
            (self.n_experts - self.top_k) * L_moe
        return full - inactive


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
ARCHS = (
    "pixtral-12b", "deepseek-moe-16b", "whisper-small", "mamba2-1.3b",
    "gemma2-27b", "mixtral-8x22b", "stablelm-12b", "zamba2-2.7b",
    "moonshot-v1-16b-a3b", "gemma2-9b", "gpt2-xl-paper",
)


def _module_name(arch: str) -> str:
    return "repro.configs." + arch.replace("-", "_").replace(".", "_")


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    mod = importlib.import_module(_module_name(arch))
    return mod.SMOKE if smoke else mod.CONFIG


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic or sliding-window variant);
# see DESIGN.md §5 for the skip rationale.
LONG_CONTEXT_OK = {
    "mamba2-1.3b", "zamba2-2.7b", "gemma2-9b", "gemma2-27b", "mixtral-8x22b",
}


def shape_applies(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_OK
    return True
