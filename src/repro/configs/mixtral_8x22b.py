"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

[arXiv:2401.04088].  56L, d_model=6144, 48H (GQA kv=8), expert d_ff=16384,
vocab=32768.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    source="arXiv:2401.04088",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    top_k=2,
    moe_d_ff=16384,
    sliding_window=4096,            # SWA on all layers (assignment sheet)
    rope_theta=1_000_000.0,
    act="silu",
    tie_embeddings=False,
)

SMOKE = CONFIG.with_(
    capacity_factor=8.0,   # no-drop in smoke tests (determinism)
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512, n_experts=4, top_k=2, moe_d_ff=512,
    sliding_window=16,
)
