"""gpt2-xl-paper — the paper's own 1.5B GPT-2 XL fine-tuning target.

[hf:gpt2-xl], used in the paper's language-modeling experiments
(WikiText2 / arXiv abstracts).  48L, d_model=1600, 25H, d_ff=6400,
vocab=50257.  We use RoPE in place of learned absolute positions
(DESIGN.md §7 — position encoding is orthogonal to AQ-SGD).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gpt2-xl-paper",
    family="dense",
    source="hf:gpt2-xl (paper §4.1)",
    num_layers=48,
    d_model=1600,
    num_heads=25,
    num_kv_heads=25,
    head_dim=64,
    d_ff=6400,
    vocab_size=50257,
    act="gelu",
    mlp_gated=False,
    tie_embeddings=True,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=512,
)
