"""gemma2-9b [dense] — local/global alternating attention + logit softcap.

[arXiv:2408.00118].  42L, d_model=3584, 16H (GQA kv=8, head_dim=256),
d_ff=14336, vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    source="arXiv:2408.00118",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    sliding_window=4096,
    local_global_period=2,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
)

SMOKE = CONFIG.with_(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512, sliding_window=16,
)
