"""moonshot-v1-16b-a3b — Moonlight-16B-A3B, fine-grained MoE (64e top-6).

[hf:moonshotai/Moonlight-16B-A3B].  48L, d_model=2048, 16H (GQA kv=16),
expert d_ff=1408, vocab=163840.  Labelled [dense] on the sheet but its
config fields are DeepSeek-style MoE; built as such (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    source="hf:moonshotai/Moonlight-16B-A3B",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=11264,                     # dense FFN width of the first layer
    vocab_size=163840,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=50_000.0,
    act="silu",
)

SMOKE = CONFIG.with_(
    capacity_factor=8.0,   # no-drop in smoke tests (determinism)
    num_layers=3, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
    d_ff=512, vocab_size=512, n_experts=4, top_k=2, n_shared_experts=1,
    moe_d_ff=128, first_dense_layers=1,
)
