"""Central accessors for every ``REPRO_*`` environment knob.

This module is the ONE place the codebase reads its environment
switches.  Nothing outside it may call ``os.environ.get("REPRO_...")``
— `tools/check_docs.py` scans the tree for strays, and also checks
that every knob in :data:`KNOBS` appears in the README env-var
reference ("Which knob do I turn"), so a new knob cannot land without
documentation.

Knob table
----------

========================  =======  ========================================
knob                      default  meaning
========================  =======  ========================================
REPRO_PALLAS_INTERPRET    ``1``    ``1`` runs every Pallas kernel in
                                   interpret mode (CPU containers); ``0``
                                   compiles via Mosaic on real TPUs.  Read
                                   once at import of `repro.kernels.ops`.
REPRO_BOUNDARY_BACKEND    unset    Overrides ``backend="auto"`` resolution
                                   for every boundary op
                                   (`core.boundary.resolve_backend`):
                                   ``reference`` or ``pallas``.  Unset:
                                   pallas on TPU, reference elsewhere.
REPRO_ONCORE_PRNG         ``0``    ``1`` opts the Pallas encode kernels
                                   into on-core PRNG stochastic rounding
                                   (TPU-only; relaxes ref<->pallas parity
                                   to the statistical gate).
========================  =======  ========================================

Accessors read ``os.environ`` at call time (except the interpret flag,
which `repro.kernels.ops` snapshots once at import, before any kernel
is built), so tests may ``monkeypatch.setenv`` freely.
"""
from __future__ import annotations

import os

# name -> (default, one-line doc).  The keys are the exported knob set
# tools/check_docs.py cross-checks against the README reference table.
KNOBS = {
    "REPRO_PALLAS_INTERPRET": (
        "1", "Pallas interpret mode (1, default) vs Mosaic compile (0)"),
    "REPRO_BOUNDARY_BACKEND": (
        "", "force the boundary codec backend: reference | pallas"),
    "REPRO_ONCORE_PRNG": (
        "0", "1 = on-core TPU PRNG stochastic rounding (statistical gate)"),
}


def _get(name: str) -> str:
    return os.environ.get(name, KNOBS[name][0])


def pallas_interpret() -> bool:
    """Whether Pallas kernels should run in interpret mode (CPU default).

    `repro.kernels.ops` snapshots this ONCE at import as its
    ``INTERPRET`` constant — the single switch point for every fused
    op."""
    return _get("REPRO_PALLAS_INTERPRET") != "0"


def boundary_backend_override() -> str:
    """The forced boundary backend ('' = no override, resolve by
    platform).  Consulted on every ``backend="auto"`` resolution."""
    return _get("REPRO_BOUNDARY_BACKEND")


def oncore_prng() -> bool:
    """Whether the on-core PRNG encode opt-in is active (TPU-only)."""
    return _get("REPRO_ONCORE_PRNG") == "1"
