"""Quantized collectives for data-parallel gradient averaging.

The paper's Fig. 5 compresses *model gradients* on the DP axis
(QuantizedAdam).  Inside shard_map the wire form is:

    s      = pmax(rowwise absmax)          (tiny, fp32)
    packed = encode_with_scale(x, s)       (b-bit packed payload)
    sum    = psum(int32 codes)             (wire: b-bit payload*)
    mean   = decode_sum_mean(sum, s, n)

Quantization is linear given a *shared* scale, so psum-of-codes
dequantizes to the exact mean of the quantized values — the classic
compressed-allreduce construction.  (*The HLO psum carries i32 lanes; a
bandwidth-optimal ring implementation exchanges the b-bit codes and
accumulates locally — the wire accounting in benchmarks uses the b-bit
payload, the dry-run's i32 psum is the conservative bound.  The
pack→unpack round trip below is kept on-device on purpose: the packed
bytes are the shippable payload and the bit-exactness anchor the
parity tests pin; a future ring keeps the pack and folds the unpack
into its accumulate step.)

Every quantize/pack/unpack step routes through `core.boundary`, the
backend-selectable fused codec (`encode_with_scale` / `decode_codes` /
`decode_sum_mean`), never the unfused jnp chain.  `ef_psum_mean_bucket`
adds QuantizedAdam-style error feedback over the bucketed gradient of
`core.grad_compress` — it is the distributed twin of
`grad_compress.compress_allreduce` and matches it bit-for-bit (int32
code sums are reduction-order exact, f32 pmax is order-independent).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import boundary as B
from repro.core import grad_compress as GC
from repro.core import quantization as Q
from repro.core.quantization import _EPS


def _axis_tuple(axis_name):
    return axis_name if isinstance(axis_name, (tuple, list)) \
        else (axis_name,)


def _fold_axis_index(key, axis_name):
    """Per-device noise key: fold_in the FLAT row-major rank along the
    (possibly compound) DP axis — the same index
    `grad_compress.worker_key` folds for simulated worker i, so
    simulation and wire draw identical noise on any mesh shape."""
    axes = _axis_tuple(axis_name)
    flat = jax.lax.axis_index(axes[0])
    for ax in axes[1:]:
        flat = flat * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    return jax.random.fold_in(key, flat)


def quantized_psum_mean(x, axis_name: str, bits: int, key,
                        stochastic: bool = True, *,
                        backend: str = "auto"):
    """Mean of x over `axis_name` with b-bit quantized payload.

    x: (..., d) float; returns f32 of the same shape.  Must be called
    inside shard_map over `axis_name`.  (``psum(1)`` of a Python scalar
    resolves statically from the axis env, so the fused receiver kernel
    gets the device count at trace time and it can never disagree with
    the mesh.)"""
    n = jax.lax.psum(1, axis_name)
    xf = x.astype(jnp.float32)
    local_s = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    s = jnp.maximum(jax.lax.pmax(local_s, axis_name), _EPS)
    packed = B.encode_with_scale(xf, s, bits=bits, stochastic=stochastic,
                                 key=key, backend=backend)
    codes = B.decode_codes(packed, bits=bits, d=x.shape[-1],
                           backend=backend)
    total = jax.lax.psum(codes, axis_name)
    return B.decode_sum_mean(total, s, bits=bits, n=n, backend=backend)


def ef_psum_mean_bucket(v_grad, err, axis_name, bits: int, key,
                        *, stochastic: bool = True,
                        backend: str = "auto"):
    """Error-feedback compressed allreduce of one gradient bucket.

    v_grad, err: (rows, group_d) f32 — this device's gradient bucket
    (`grad_compress.flatten_bucket`) and carried error.  Returns
    (mean bucket, new error).  Must run inside shard_map over
    `axis_name`; the worker count comes from the axis env itself.

    The noise key is folded by axis position internally, so callers pass
    the same base key on every device."""
    n = jax.lax.psum(1, axis_name)
    v = v_grad.astype(jnp.float32) + err
    s = jnp.maximum(jax.lax.pmax(GC.local_scale(v), axis_name), _EPS)
    packed, new_err = GC.ef_encode(
        v, s, bits, _fold_axis_index(key, axis_name),
        stochastic=stochastic, backend=backend)
    codes = B.decode_codes(packed, bits=bits, d=v.shape[-1],
                           backend=backend)
    total = jax.lax.psum(codes, axis_name)
    mean = B.decode_sum_mean(total, s, bits=bits, n=n, backend=backend)
    return mean, new_err


def psum_wire_bytes(shape, bits: int) -> int:
    """Ring-allreduce wire bytes per device for the quantized payload."""
    return 2 * Q.wire_bytes(shape, bits)
