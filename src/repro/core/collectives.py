"""Quantized collectives for data-parallel gradient averaging.

The paper's Fig. 5 compresses *model gradients* on the DP axis
(QuantizedAdam).  Inside shard_map the natural wire form is:

    s      = pmax(rowwise absmax)          (tiny, fp32)
    codes  = quantize(x, shared scale s)   (b-bit, stochastic)
    sum    = psum(codes as int32)          (wire: b-bit payload*)
    mean   = dequantize(sum) / n_devices

Quantization is linear given a *shared* scale, so psum-of-codes
dequantizes to the exact mean of the quantized values — this is the
classic compressed-allreduce construction.  (*The HLO psum carries i32
lanes; a bandwidth-optimal ring implementation exchanges the b-bit codes
and accumulates locally — the wire accounting in benchmarks uses the
b-bit payload, the dry-run's i32 psum is the conservative bound.)

Combine with error feedback (core.grad_compress) at the call site.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantization as Q


def quantized_psum_mean(x, axis_name: str, bits: int, key,
                        stochastic: bool = True):
    """Mean of x over `axis_name` with b-bit quantized payload.

    x: (..., d) float; returns f32 of the same shape.  Must be called
    inside shard_map over `axis_name`."""
    n = jax.lax.psum(1, axis_name)
    xf = x.astype(jnp.float32)
    local_s = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    s = jnp.maximum(jax.lax.pmax(local_s, axis_name), 1e-12)
    codes, _ = Q.quantize(xf, bits, stochastic=stochastic, key=key,
                          scale=s)
    total = jax.lax.psum(codes.astype(jnp.int32), axis_name)
    levels = (1 << bits) - 1
    # sum of dequantized values: sum_i (c_i * 2/L - 1) * s
    mean = (total.astype(jnp.float32) * (2.0 / levels) - n) * s / n
    return mean


def psum_wire_bytes(shape, bits: int) -> int:
    """Ring-allreduce wire bytes per device for the quantized payload."""
    return 2 * Q.wire_bytes(shape, bits)
