"""Quantized collectives for data-parallel gradient averaging.

The paper's Fig. 5 compresses *model gradients* on the DP axis
(QuantizedAdam).  Two wire forms carry the same math:

* ``ef_psum_mean_bucket`` — the conservative psum wire:

      s      = pmax(rowwise absmax)          (tiny, fp32)
      codes  = encode_codes_with_scale(x, s) (int32 accumulator form)
      sum    = psum(int32 codes)             (HLO: i32 lanes)
      mean   = decode_sum_mean(sum, s, n)

* ``ring_ef_reduce_mean_bucket`` — the bandwidth-optimal ring: the SAME
  encode additionally emits the packed b-bit payload (one fused pass),
  and the collective ships that payload itself.  Reduce-scatter half:
  the bucket is cut into N row segments; at step t every device
  ``ppermute``s its own packed codes of segment (i+t) mod N straight to
  that segment's owner (a rotation-by-t permutation — N-1 steps, one
  packed segment per device per step, exactly ``Q.wire_bytes`` of
  payload per hop), and the owner folds the unpack into a fused
  int32 unpack-accumulate (`B.accumulate_codes`).  All-gather half: the
  owner's segment *sums* are packed at ``Q.sum_wire_bits(bits, n)`` =
  b + ceil(log2 n) bits (`B.pack_sums`) and rotated to every device the
  same way.  Every device then unpacks the full code-sum bucket and
  runs the SAME ``decode_sum_mean``.

  Because int32 code sums are exact in every addition order and the
  shared scale is an order-independent f32 max, the ring is
  BIT-IDENTICAL to the psum wire and to the simulator's
  `grad_compress.compress_allreduce` on any mesh shape — including
  compound (pod, data) axes (``ppermute``/``axis_index`` take the axis
  tuple; rotations act on the flat row-major rank, the same index the
  noise keys fold) and non-power-of-two ring sizes (the last segment is
  ragged and zero-padded; padded rows carry zero codes and are sliced
  off).  That parity is the correctness anchor: the ring lands as a
  pure wire-cost change.

  The log2(n) growth of the all-gather payload is the price of
  exactness — re-quantizing the decoded mean would ship b bits in both
  halves but double-quantizes, breaking the parity anchor (and the
  EF telescoping analysis).  `ring_wire_bytes` models the realized
  bytes precisely; `launch/hlo_cost.py` + tests/test_hlo_cost.py pin
  them against the traced HLO.

* ``ring_ef_reduce_scatter_bucket`` — the ZeRO-sharded wire: the SAME
  ring, stopped at the segment midpoint.  After the reduce-scatter half
  every rank already holds the exact int32 code sum of its OWN segment;
  instead of all-gathering packed sums, each rank decodes just that
  segment's mean (`decode_sum_mean` on one (seg, d) slice) and keeps
  it.  No second collective half at all: the sharded wire ships only
  the n-1 packed b-bit segment hops plus the scale ``pmax``
  (`ring_wire_bytes(..., sharded=True)`), and the downstream optimizer
  is expected to be partitioned to segment owners (see
  `training/pipeline.py` ``dp_wire="ring-sharded"`` and
  `optim/adamw.py::apply_bucket_updates`) with the parameter
  all-gather — which ZeRO-3 performs anyway — closing the loop.
  Because the owned segment's code sum is the SAME exact int32 sum the
  full ring holds at its midpoint, the sharded wire's segment means are
  BIT-IDENTICAL to the corresponding rows of `ef_psum_mean_bucket` /
  `ring_ef_reduce_mean_bucket` / the simulator's
  `grad_compress.compress_reduce_scatter`, including on distinct
  per-rank (local) gradient buckets.  Padded rows of a ragged last
  segment carry zero codes AND a zero scale, so they decode to
  (sign-preserving) zeros on both backends.

Quantization is linear given a *shared* scale, so a sum of codes
dequantizes to the exact mean of the quantized values — the classic
compressed-allreduce construction.  Every quantize/pack/unpack step
routes through `core.boundary`, the backend-selectable fused codec,
never the unfused jnp chain.  `ef_psum_mean_bucket` and the ring add
QuantizedAdam-style error feedback over the bucketed gradient of
`core.grad_compress`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import boundary as B
from repro.core import grad_compress as GC
from repro.core import quantization as Q
from repro.core.quantization import _EPS

# Legacy constant: the codec wires THIS module implements.  The
# canonical wire list is the registry (`repro.comm.wires`), which also
# carries wires this module never special-cases (e.g. the fp16
# passthrough) — derive wire choices from there, not from this tuple.
WIRES = ("psum", "ring", "ring-sharded")

# the ONE segment-geometry source (defined next to the bucket layout
# to avoid a circular import; both names are public API)
ring_segment_rows = GC.ring_segment_rows


def ring_chunk_bounds(seg: int, chunks: int) -> tuple:
    """Row bounds that cut one ``seg``-row ring segment into ``chunks``
    chunks — the single chunk-geometry source of the double-buffered
    ring schedule, derived from `ring_segment_rows` itself (chunk width
    = ``ring_segment_rows(seg, chunks)``, the same ceil-division that
    cuts the bucket into segments).

    Returns a tuple of ``(lo, hi)`` half-open row ranges that partition
    ``range(seg)`` exactly: disjoint, covering, in order, with only the
    LAST chunk possibly ragged (shorter).  When ``chunks`` does not
    divide ``seg`` the realized chunk count may be smaller than
    requested (ceil-division minimality) — callers iterate the returned
    bounds, never ``range(chunks)``.

    Invalid chunk counts raise loudly: ``chunks`` must be a positive
    int no larger than ``seg`` (a chunk carries at least one row)."""
    if not isinstance(chunks, int) or isinstance(chunks, bool) \
            or chunks < 1:
        raise ValueError(
            f"chunks={chunks!r} is invalid: the ring chunk count must "
            f"be a positive int — did you mean chunks=1 (the "
            f"monolithic schedule)?")
    if chunks > seg:
        raise ValueError(
            f"chunks={chunks} exceeds the segment's {seg} rows (each "
            f"chunk ships at least one row per hop); valid range is "
            f"1..{seg} — did you mean chunks={seg}?")
    cw = ring_segment_rows(seg, chunks)
    return tuple((lo, min(lo + cw, seg)) for lo in range(0, seg, cw))


def _axis_tuple(axis_name):
    return axis_name if isinstance(axis_name, (tuple, list)) \
        else (axis_name,)


def _flat_axis_index(axis_name):
    """Flat row-major rank along the (possibly compound) DP axis —
    `axis_index` accepts the axis tuple and matches the index
    `_fold_axis_index` folds into the noise keys."""
    axes = _axis_tuple(axis_name)
    return jax.lax.axis_index(axes if len(axes) > 1 else axes[0])


def _fold_axis_index(key, axis_name):
    """Per-device noise key: fold_in the FLAT row-major rank along the
    (possibly compound) DP axis — the same index
    `grad_compress.worker_key` folds for simulated worker i, so
    simulation and wire draw identical noise on any mesh shape."""
    axes = _axis_tuple(axis_name)
    flat = jax.lax.axis_index(axes[0])
    for ax in axes[1:]:
        flat = flat * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    return jax.random.fold_in(key, flat)


def quantized_psum_mean(x, axis_name: str, bits: int, key,
                        stochastic: bool = True, *,
                        backend: str = "auto"):
    """Mean of x over `axis_name` with b-bit quantized payload.

    x: (..., d) float; returns f32 of the same shape.  Must be called
    inside shard_map over `axis_name`.  (``psum(1)`` of a Python scalar
    resolves statically from the axis env, so the fused receiver kernel
    gets the device count at trace time and it can never disagree with
    the mesh.)  Uses the codes-only encode — the same single entry
    point as the gradient wires — so there is no on-device pack→unpack
    round trip."""
    n = jax.lax.psum(1, axis_name)
    xf = x.astype(jnp.float32)
    local_s = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    s = jnp.maximum(jax.lax.pmax(local_s, axis_name), _EPS)
    codes = B.encode_codes_with_scale(xf, s, bits=bits,
                                      stochastic=stochastic, key=key,
                                      backend=backend)
    total = jax.lax.psum(codes, axis_name)
    return B.decode_sum_mean(total, s, bits=bits, n=n, backend=backend)


def ef_psum_mean_bucket(v_grad, err, axis_name, bits: int, key,
                        *, stochastic: bool = True,
                        backend: str = "auto"):
    """Error-feedback compressed allreduce of one gradient bucket
    (psum form: the collective carries i32 lanes — the conservative
    bound the ring improves on).

    v_grad, err: (rows, group_d) f32 — this device's gradient bucket
    (`grad_compress.flatten_bucket`) and carried error.  Returns
    (mean bucket, new error).  Must run inside shard_map over
    `axis_name`; the worker count comes from the axis env itself.

    The noise key is folded by axis position internally, so callers pass
    the same base key on every device."""
    n = jax.lax.psum(1, axis_name)
    v = v_grad.astype(jnp.float32) + err
    s = jnp.maximum(jax.lax.pmax(GC.local_scale(v), axis_name), _EPS)
    _, codes, new_err = GC.ef_encode(
        v, s, bits, _fold_axis_index(key, axis_name),
        stochastic=stochastic, backend=backend)
    total = jax.lax.psum(codes, axis_name)
    mean = B.decode_sum_mean(total, s, bits=bits, n=n, backend=backend)
    return mean, new_err


def _reduce_scatter_codes(packed, codes, n, ax, axis_name, bits,
                          backend):
    """The ring's reduce-scatter half, shared by the full ring and the
    ZeRO-sharded wire: rotate packed code segments to their owners and
    fold each arriving segment into the local int32 accumulator.

    Returns (acc, seg, i): this rank's exact (seg, d) code sum of its
    OWN segment, the segment row count, and the rank's flat ring index.
    Padded rows of a ragged last segment carry zero payload, so they
    accumulate zero sums."""
    rows, d = codes.shape
    pw = packed.shape[-1]
    seg = ring_segment_rows(rows, n)
    pad = seg * n - rows
    if pad:
        # zero payload rows: they unpack to zero codes, accumulate to
        # zero sums, and are sliced off (full ring) or decoded against
        # a zero scale (sharded wire) before touching the optimizer
        packed = jnp.pad(packed, ((0, pad), (0, 0)))
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    psegs = packed.reshape(n, seg, pw)
    csegs = codes.reshape(n, seg, d)
    i = _flat_axis_index(axis_name)

    acc = jax.lax.dynamic_index_in_dim(csegs, i, 0, keepdims=False)
    for t in range(1, n):
        perm = [(src, (src + t) % n) for src in range(n)]
        send = jax.lax.dynamic_index_in_dim(psegs, (i + t) % n, 0,
                                            keepdims=False)
        recv = jax.lax.ppermute(send, ax, perm)
        acc = B.accumulate_codes(recv, acc, bits=bits, backend=backend)
    return acc, seg, i


def make_chunk_encoder(v, s, bits: int, key, n: int, bounds,
                       *, stochastic: bool = True,
                       backend: str = "auto"):
    """Per-chunk encoder for the double-buffered ring, BIT-IDENTICAL to
    the monolithic `grad_compress.ef_encode` sender per row.

    ``v``/``s``: the compensated (rows, group_d) bucket and its shared
    rowwise scale; ``bounds``: `ring_chunk_bounds` output over
    ``seg = ring_segment_rows(rows, n)``.  Returns ``enc(ci)`` mapping
    a chunk index to ``(packed, codes)`` of shape ``(n, cw, ·)`` — the
    packed payload and int32 codes of chunk ``ci``'s rows across ALL
    ``n`` device segments (what the rotation hops slice senders from).

    Bit-parity with the monolithic encode rests on two invariants,
    both regression-gated (tests/test_grad_compress.py,
    tests/test_properties.py):

    * the full-bucket stochastic noise is drawn ONCE here with the
      same ``jax.random.uniform(key, v.shape)`` call the boundary's
      `_noise` makes, then row-sliced per chunk — so every live row
      quantizes against the identical noise value regardless of K
      (the explicit ``noise=`` argument also bypasses the on-core
      PRNG opt-in, whose stream is grid-position-dependent and
      therefore not chunking-invariant);
    * pad rows of a ragged LAST segment are zeroed in code space
      after encoding (a static mask), matching the monolithic path's
      zero-padding of the encoded arrays exactly — quantize(0) under
      a shared scale is NOT zero, so masking must happen after."""
    rows, d = v.shape
    seg = ring_segment_rows(rows, n)
    pad = seg * n - rows
    noise = jax.random.uniform(key, v.shape, jnp.float32) \
        if stochastic else None

    def _padded(a):
        return jnp.pad(a, ((0, pad), (0, 0))) if pad else a

    v3 = _padded(v).reshape(n, seg, d)
    s3 = _padded(s).reshape(n, seg, 1)
    u3 = _padded(noise).reshape(n, seg, d) if stochastic else None

    def enc(ci):
        lo, hi = bounds[ci]
        cw = hi - lo
        vs = v3[:, lo:hi].reshape(n * cw, d)
        ss = s3[:, lo:hi].reshape(n * cw, 1)
        us = u3[:, lo:hi].reshape(n * cw, d) if stochastic else None
        packed, codes = B.encode_codes_with_scale(
            vs, ss, bits=bits, stochastic=stochastic, key=key,
            noise=us, pack=True, backend=backend)
        packed = packed.reshape(n, cw, -1)
        codes = codes.reshape(n, cw, d)
        if pad:
            gidx = np.arange(n)[:, None] * seg \
                + np.arange(lo, hi)[None, :]
            live = gidx < rows
            if not live.all():
                live_j = jnp.asarray(live)[..., None]
                packed = jnp.where(live_j, packed, 0)
                codes = jnp.where(live_j, codes, 0)
        return packed, codes

    return enc


def _chunked_reduce_scatter(v, s, n, ax, axis_name, bits, key,
                            *, stochastic, backend, chunks):
    """The ring's reduce-scatter half, chunked and double-buffered:
    while chunk ``c``'s rotation hops are in flight, chunk ``c+1``
    encodes (the encode is issued between posting the ppermutes and
    consuming their results, so the compiler is free to overlap it
    with the hops).  Ships exactly the same bytes as
    `_reduce_scatter_codes` — chunking changes scheduling, never
    payload — and is bit-identical to it (int32 code sums are exact
    in any order; the encoder is row-sliced, see `make_chunk_encoder`).

    Returns ``(acc, seg, i, new_err)``: the rank's exact (seg, d) own-
    segment code sum, the segment rows, the flat ring index, and the
    error-feedback carry (computed from the reassembled full-bucket
    codes exactly as `grad_compress.ef_encode` does)."""
    rows, d = v.shape
    seg = ring_segment_rows(rows, n)
    bounds = ring_chunk_bounds(seg, chunks)
    enc = make_chunk_encoder(v, s, bits, key, n, bounds,
                             stochastic=stochastic, backend=backend)
    i = _flat_axis_index(axis_name)

    accs, code_chunks = [], []
    packed_c, codes_c = enc(0)
    for ci in range(len(bounds)):
        code_chunks.append(codes_c)
        acc = jax.lax.dynamic_index_in_dim(codes_c, i, 0,
                                           keepdims=False)
        recvs = []
        for t in range(1, n):
            perm = [(src, (src + t) % n) for src in range(n)]
            send = jax.lax.dynamic_index_in_dim(
                packed_c, (i + t) % n, 0, keepdims=False)
            recvs.append(jax.lax.ppermute(send, ax, perm))
        if ci + 1 < len(bounds):
            # double buffer: encode the NEXT chunk while this chunk's
            # hops are in flight (data-independent of the recvs)
            packed_c, codes_c = enc(ci + 1)
        for recv in recvs:
            acc = B.accumulate_codes(recv, acc, bits=bits,
                                     backend=backend)
        accs.append(acc)

    acc = jnp.concatenate(accs, axis=0) if len(accs) > 1 else accs[0]
    codes_full = jnp.concatenate(code_chunks, axis=1) \
        if len(code_chunks) > 1 else code_chunks[0]
    codes_flat = codes_full.reshape(n * seg, d)[:rows]
    q = B.decode_sum_mean(codes_flat, s, bits=bits, n=1,
                          backend=backend)
    return acc, seg, i, v - q


def ring_ef_reduce_scatter_bucket(v_grad, err, axis_name, bits: int, key,
                                  *, stochastic: bool = True,
                                  backend: str = "auto",
                                  chunks: int = 1):
    """ZeRO-sharded error-feedback compressed reduce-scatter: the ring
    stopped at the segment midpoint — each rank keeps only its OWN
    segment's mean; there is no all-gather of sums at all.

    v_grad, err: (rows, group_d) f32 — this rank's (possibly local /
    per-rank-distinct) gradient bucket and carried full-bucket error.
    Returns (own segment mean (seg, group_d) with
    seg = `ring_segment_rows(rows, n)`, new error (rows, group_d)).
    Must run inside shard_map over `axis_name` (a name or axis tuple).

    The owned segment's int32 code sum is the SAME exact sum the full
    ring holds at its midpoint, so the returned rows are bit-identical
    to the corresponding rows of `ring_ef_reduce_mean_bucket` /
    `ef_psum_mean_bucket` and to
    `grad_compress.compress_reduce_scatter` in the simulator.  Rows of
    a ragged last segment beyond the bucket decode against a ZERO
    scale (zero codes, zero scale -> sign-preserving zeros on both
    backends) and must be dropped by the caller before they touch
    parameters — `training/pipeline.py` drops them when unflattening
    the updated parameter bucket.

    Error feedback stays FULL-bucket per rank: every rank encodes its
    whole compensated bucket (it must, to ship every segment to its
    owner), so the carried error is the same (rows, group_d) state the
    other wires carry — only the *reduced gradient* is sharded.

    ``chunks`` > 1 runs the reduce-scatter half chunked and
    double-buffered (`_chunked_reduce_scatter`) — bit-identical,
    byte-identical, scheduling-only; ``chunks=1`` is the exact
    monolithic code path.  Invalid chunk counts raise loudly
    (`ring_chunk_bounds`)."""
    axes = _axis_tuple(axis_name)
    ax = axes if len(axes) > 1 else axes[0]
    n = jax.lax.psum(1, axis_name)
    v = v_grad.astype(jnp.float32) + err
    s = jnp.maximum(jax.lax.pmax(GC.local_scale(v), axis_name), _EPS)
    kf = _fold_axis_index(key, axis_name)
    if chunks != 1:
        # validate loudly even on paths that cannot overlap (n == 1)
        ring_chunk_bounds(ring_segment_rows(v.shape[0], n), chunks)
    if chunks == 1 or n == 1:
        packed, codes, new_err = GC.ef_encode(
            v, s, bits, kf, stochastic=stochastic, backend=backend,
            pack=True)
        if n == 1:
            mean = B.decode_sum_mean(codes, s, bits=bits, n=1,
                                     backend=backend)
            return mean, new_err
        acc, seg, i = _reduce_scatter_codes(packed, codes, n, ax,
                                            axis_name, bits, backend)
    else:
        acc, seg, i, new_err = _chunked_reduce_scatter(
            v, s, n, ax, axis_name, bits, kf, stochastic=stochastic,
            backend=backend, chunks=chunks)
    rows = v.shape[0]
    pad = seg * n - rows
    s_pad = jnp.pad(s, ((0, pad), (0, 0))) if pad else s
    s_own = jax.lax.dynamic_index_in_dim(
        s_pad.reshape(n, seg, 1), i, 0, keepdims=False)
    seg_mean = B.decode_sum_mean(acc, s_own, bits=bits, n=n,
                                 backend=backend)
    return seg_mean, new_err


def ring_ef_reduce_mean_bucket(v_grad, err, axis_name, bits: int, key,
                               *, stochastic: bool = True,
                               backend: str = "auto",
                               chunks: int = 1):
    """Error-feedback compressed allreduce as a bandwidth-optimal ring:
    packed b-bit codes ship on the wire, accumulation is local.

    Drop-in replacement for `ef_psum_mean_bucket` — same signature,
    BIT-IDENTICAL result on every mesh shape (see module docstring).
    Must run inside shard_map over `axis_name` (a name or an axis
    tuple); the ring size n and the segment schedule resolve statically
    from the axis env.

    Schedule (n = ring size, device i, segment j owned by device j):

      reduce-scatter: for t in 1..n-1, ship MY packed codes of segment
        (i+t) mod n to its owner via the rotation-by-t ppermute; fold
        each arriving segment into my int32 accumulator with the fused
        unpack-accumulate.  After n-1 steps I hold the exact code sum
        of my own segment.
      all-gather: pack my segment sums at b + ceil(log2 n) bits and
        rotate them to every device the same way; unpack all segments
        and decode the mean locally.

    ``chunks`` > 1 chunks and double-buffers the reduce-scatter half
    (`_chunked_reduce_scatter`) — bit-identical, byte-identical,
    scheduling-only; ``chunks=1`` is the exact monolithic code path.
    Invalid chunk counts raise loudly (`ring_chunk_bounds`).
    """
    axes = _axis_tuple(axis_name)
    ax = axes if len(axes) > 1 else axes[0]
    n = jax.lax.psum(1, axis_name)
    v = v_grad.astype(jnp.float32) + err
    s = jnp.maximum(jax.lax.pmax(GC.local_scale(v), axis_name), _EPS)
    kf = _fold_axis_index(key, axis_name)
    if chunks != 1:
        # validate loudly even on paths that cannot overlap (n == 1)
        ring_chunk_bounds(ring_segment_rows(v.shape[0], n), chunks)
    if chunks == 1 or n == 1:
        packed, codes, new_err = GC.ef_encode(
            v, s, bits, kf, stochastic=stochastic, backend=backend,
            pack=True)
        if n == 1:
            mean = B.decode_sum_mean(codes, s, bits=bits, n=1,
                                     backend=backend)
            return mean, new_err
        acc, seg, i = _reduce_scatter_codes(packed, codes, n, ax,
                                            axis_name, bits, backend)
    else:
        acc, seg, i, new_err = _chunked_reduce_scatter(
            v, s, n, ax, axis_name, bits, kf, stochastic=stochastic,
            backend=backend, chunks=chunks)
    rows, d = v.shape

    # ---- all-gather: rotate the packed segment sums to everyone --------
    own = B.pack_sums(acc, bits=bits, n=n, backend=backend)
    gathered = jnp.zeros((n,) + own.shape, jnp.uint8)
    gathered = jax.lax.dynamic_update_index_in_dim(gathered, own, i, 0)
    for t in range(1, n):
        perm = [(src, (src + t) % n) for src in range(n)]
        recv = jax.lax.ppermute(own, ax, perm)
        gathered = jax.lax.dynamic_update_index_in_dim(
            gathered, recv, (i - t) % n, 0)

    total_p = gathered.reshape(n * seg, -1)[:rows]
    total = B.unpack_sums(total_p, bits=bits, n=n, d=d, backend=backend)
    mean = B.decode_sum_mean(total, s, bits=bits, n=n, backend=backend)
    return mean, new_err


def ring_wire_bytes(shape, bits: int, n: int = 2, *,
                    sharded: bool = False, chunks: int = 1) -> int:
    """Collective bytes of the compressed ring for one (rows, d) bucket
    on an n-device ring — exact, matching what `launch/hlo_cost`
    measures on the traced program (tests/test_hlo_cost.py pins this):

    * reduce-scatter: n-1 ppermutes of one packed b-bit segment
      (~ (n-1)/n of the bucket's packed payload per device);
    * all-gather (full ring only): n-1 ppermutes of one packed
      code-SUM segment at b + ceil(log2 n) bits (`Q.sum_wire_bits` —
      the exactness overhead);
    * plus the fp32 scale ``pmax`` (one f32 per bucket row).

    sharded=True models `ring_ef_reduce_scatter_bucket`: the ring
    stopped at the midpoint, so the all-gather term vanishes and only
    the b-bit reduce-scatter hops and the scale pmax remain — strictly
    fewer bytes than the full ring at every b whenever n > 1.

    ``chunks`` is accepted (and validated via `ring_chunk_bounds`)
    because the chunked schedule ships IDENTICAL total bytes: the
    per-hop chunk payloads of one segment sum to exactly the
    monolithic segment payload (packing is per-row, so chunk widths
    add).  tests/test_hlo_cost.py pins the chunked wires' compiled
    collective bytes against this same model.
    """
    rows, d = shape
    seg = ring_segment_rows(rows, n)
    if chunks != 1:
        ring_chunk_bounds(seg, chunks)   # bytes unchanged, validate only
    hops = max(n - 1, 0)
    gather = 0 if sharded else hops * seg * Q.sum_packed_width(d, bits, n)
    return (hops * seg * Q.packed_width(d, bits)
            + gather
            + rows * 4)


# Historical name: pre-ring accounting estimated the compressed psum as
# 2x the packed payload.  Since the ring landed, the realized wire IS
# the ring, so the old entry point resolves to its exact model.
psum_wire_bytes = ring_wire_bytes
