"""Core algorithm layer: the paper's math, framework-level only.

* `quantization` — the uniform quantizer Q (§4.1), dense bit-packing,
  and the code-SUM packing of the compressed ring;
* `boundary` — the backend-selectable fused boundary-op table every
  wire crossing routes through (reference jnp chain | Pallas kernels);
* `aqsgd` — Algorithm 2: message buffers and the boundary map;
* `grad_compress` — the bucketed error-feedback gradient codec
  (QuantizedAdam, Fig. 5) and its single-process simulations;
* `collectives` — the three shard_map DP gradient wires (psum / ring /
  ZeRO-sharded reduce-scatter).

See docs/ARCHITECTURE.md for the full map.
"""
