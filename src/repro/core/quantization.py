"""Uniform activation quantization used by AQ-SGD and DirectQ.

The paper's Q (§4.1): normalize a vector into [-1, 1] by its absolute
maximum and partition the range uniformly into 2**b intervals
(Chakrabarti & Moseley 2019).  The theory (Thm 3.1) requires Q to be
*unbiased* with relative error ``E||x - Q(x)|| <= c_Q ||x||`` — satisfied
here by stochastic rounding on the uniform grid (the grid always covers
the input because the scale is the absmax).

Two forms are provided:

* ``quantize`` / ``dequantize`` / ``pack_codes`` / ``unpack_codes`` — the
  *wire* form.  Codes are uint8 (2/4/8 bits packed densely) plus a float
  scale per row; this is the payload that actually crosses the pipeline
  boundary (``ppermute``), so compiled collective bytes shrink by the
  true compression ratio.
* ``qdq`` — quantize→dequantize "fake quant" used by the bit-faithful
  simulated trainer; numerically identical to a wire round-trip.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

_EPS = 1e-12


def absmax_scale(x: jax.Array, per_row: bool = True) -> jax.Array:
    """Positive scale such that x/scale ∈ [-1, 1].

    per_row=True gives one scale per trailing-dim row (the paper's
    per-vector normalization); False gives a single per-tensor scale.
    """
    x = x.astype(jnp.float32)
    if per_row:
        s = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    else:
        s = jnp.max(jnp.abs(x))
    return jnp.maximum(s, _EPS)


def _grid_positions(x: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Map x into continuous grid coordinates [0, 2**bits - 1]."""
    levels = (1 << bits) - 1
    y = (x.astype(jnp.float32) / scale + 1.0) * (0.5 * levels)
    return jnp.clip(y, 0.0, float(levels))


def quantize(
    x: jax.Array,
    bits: int,
    *,
    stochastic: bool = True,
    key: Optional[jax.Array] = None,
    per_row: bool = True,
    scale: Optional[jax.Array] = None,
    noise: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Quantize to uint8 codes in [0, 2**bits - 1] plus float32 scale.

    Stochastic rounding draws from `key`, or consumes pre-drawn uniform
    `noise` of x.shape (``noise < frac`` is exactly what bernoulli(key,
    frac) computes, so both routes are bit-identical for the same key —
    the noise route is what keeps the Pallas backend in lockstep)."""
    assert 1 <= bits <= 8, bits
    if scale is None:
        scale = absmax_scale(x, per_row=per_row)
    y = _grid_positions(x, scale, bits)
    if stochastic:
        lo = jnp.floor(y)
        frac = y - lo
        if noise is not None:
            bump = (noise < frac).astype(jnp.float32)
        elif key is not None:
            bump = jax.random.bernoulli(key, frac).astype(jnp.float32)
        else:
            raise ValueError("stochastic quantization needs a PRNG key "
                             "or a uniform noise tensor")
        codes = lo + bump
    else:
        codes = jnp.round(y)
    return codes.astype(jnp.uint8), scale


def dequantize(codes: jax.Array, scale: jax.Array, bits: int,
               dtype: jnp.dtype = jnp.float32) -> jax.Array:
    """Map b-bit codes back to values: the center of each grid cell,
    scaled — the inverse the whole parity contract rounds through."""
    # ((2c - levels) * scale) / levels, in this exact association: 2c -
    # levels is integer-exact in f32 (immune to FMA contraction), and the
    # trailing division cannot contract with a downstream add — so every
    # compilation of this chain (XLA CPU, fused Pallas kernel, eager)
    # rounds identically.  The bit-identical reference/pallas boundary
    # backend contract depends on this shape; don't "simplify" it to
    # (c * (2/levels) - 1) * scale.
    levels = (1 << bits) - 1
    ic = codes.astype(jnp.float32) * 2.0 - float(levels)
    return ((ic * scale) / levels).astype(dtype)


def qdq(
    x: jax.Array,
    bits: int,
    *,
    stochastic: bool = True,
    key: Optional[jax.Array] = None,
    per_row: bool = True,
) -> jax.Array:
    """Fake-quantization round trip; preserves input dtype."""
    codes, scale = quantize(x, bits, stochastic=stochastic, key=key,
                            per_row=per_row)
    return dequantize(codes, scale, bits, dtype=x.dtype)


# ---------------------------------------------------------------------------
# Dense bit-packing — the wire format.
# ---------------------------------------------------------------------------

def codes_per_byte(bits: int) -> int:
    """How many b-bit codes pack into one wire byte (byte-aligned
    widths only)."""
    assert bits in (1, 2, 4, 8), f"packing supports 1/2/4/8 bits, got {bits}"
    return 8 // bits


def packed_width(n: int, bits: int) -> int:
    """Packed bytes per row.  Byte-aligned (1/2/4/8 bit) formats pack k
    codes/byte; other widths (e.g. the paper's fw3/bw6) are bit-packed —
    width is ceil(n*bits/8)."""
    if bits in (1, 2, 4, 8):
        k = codes_per_byte(bits)
        return (n + k - 1) // k
    return (n * bits + 7) // 8


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """Pack uint8 codes (< 2**bits) densely along the last axis."""
    k = codes_per_byte(bits)
    if k == 1:
        return codes
    n = codes.shape[-1]
    pad = (-n) % k
    if pad:
        codes = jnp.pad(codes, [(0, 0)] * (codes.ndim - 1) + [(0, pad)])
    grouped = codes.reshape(*codes.shape[:-1], -1, k).astype(jnp.uint32)
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)
    packed = jnp.sum(grouped << shifts, axis=-1)
    return packed.astype(jnp.uint8)


def unpack_codes(packed: jax.Array, bits: int, n: int) -> jax.Array:
    """Inverse of pack_codes; n = original last-axis length."""
    k = codes_per_byte(bits)
    if k == 1:
        return packed[..., :n]
    shifts = (jnp.arange(k, dtype=jnp.uint32) * bits)
    mask = jnp.uint32((1 << bits) - 1)
    vals = (packed[..., None].astype(jnp.uint32) >> shifts) & mask
    flat = vals.reshape(*packed.shape[:-1], -1)
    return flat[..., :n].astype(jnp.uint8)


def wire_bytes(shape: tuple[int, ...], bits: int,
               scale_bytes: int = 4) -> int:
    """Bytes on the wire for a quantized tensor with per-row scales."""
    *rows, n = shape
    nrows = int(functools.reduce(lambda a, b: a * b, rows, 1))
    return nrows * packed_width(n, bits) + nrows * scale_bytes


# ---------------------------------------------------------------------------
# Code-SUM packing — the all-gather half of the compressed ring collective.
#
# A sum of n b-bit codes is at most n*(2**b - 1): it no longer fits b bits,
# but it fits b + ceil(log2 n) — the log2(n) growth is the price of keeping
# the ring bit-identical to ``psum(int32 codes)`` (re-quantizing the mean
# would stay at b bits both phases but double-quantizes, breaking the
# parity anchor).  Sums are packed densely at the narrowest supported
# width: sub-byte widths reuse the dense code packer, 16/32-bit widths
# split little-endian into u8 wire bytes.
# ---------------------------------------------------------------------------

SUM_WIRE_WIDTHS = (1, 2, 4, 8, 16, 32)


def sum_wire_bits(bits: int, n: int) -> int:
    """Narrowest packing width (in bits) holding any sum of n b-bit codes."""
    assert n >= 1 and 1 <= bits <= 8, (bits, n)
    maxv = n * ((1 << bits) - 1)
    for sw in SUM_WIRE_WIDTHS:
        if maxv <= (1 << sw) - 1:
            return sw
    raise ValueError(f"code sums for bits={bits}, n={n} exceed 32 bits")


def sum_packed_width(d: int, bits: int, n: int) -> int:
    """Packed wire bytes per row of d code sums over n workers."""
    sw = sum_wire_bits(bits, n)
    if sw <= 8:
        k = 8 // sw
        return (d + k - 1) // k
    return d * (sw // 8)


def pack_sums(total: jax.Array, bits: int, n: int) -> jax.Array:
    """int32 code sums over n workers -> dense u8 payload
    (`sum_wire_bits(bits, n)` bits per sum along the last axis)."""
    sw = sum_wire_bits(bits, n)
    if sw <= 8:
        # sums < 2**sw <= 256 by construction: the code packer applies
        return pack_codes(total.astype(jnp.uint8), sw)
    nb = sw // 8
    t = total.astype(jnp.uint32)
    shifts = jnp.arange(nb, dtype=jnp.uint32) * 8
    b = (t[..., None] >> shifts) & jnp.uint32(0xFF)
    return b.reshape(*t.shape[:-1], -1).astype(jnp.uint8)


def unpack_sums(packed: jax.Array, bits: int, n: int, d: int) -> jax.Array:
    """Inverse of `pack_sums`; d = original last-axis length.  int32."""
    sw = sum_wire_bits(bits, n)
    if sw <= 8:
        return unpack_codes(packed, sw, d).astype(jnp.int32)
    nb = sw // 8
    shifts = jnp.arange(nb, dtype=jnp.uint32) * 8
    b = packed.astype(jnp.uint32).reshape(*packed.shape[:-1], -1, nb)
    vals = jnp.sum(b << shifts, axis=-1)
    return vals[..., :d].astype(jnp.int32)


__all__ = [
    "absmax_scale", "quantize", "dequantize", "qdq",
    "codes_per_byte", "packed_width", "pack_codes", "unpack_codes",
    "wire_bytes",
    "sum_wire_bits", "sum_packed_width", "pack_sums", "unpack_sums",
]
