"""AQ-SGD: activation-delta compression at pipeline boundaries.

Implements Algorithm 1/2 of the paper in functional JAX form:

* per-(boundary, sample) message buffers ``m(ξ)`` — both sides of a real
  boundary keep bit-identical copies because both apply the *same*
  quantized delta; functionally we carry one logical buffer;
* first-visit sends full precision (``seen`` mask);
* later visits send ``Q(a(ξ, x_t) − m(ξ))`` and update
  ``m(ξ) ← m(ξ) + Q(·)``;
* machine b computes on ``m(ξ)``, i.e. the boundary is a straight-through
  estimator: forward value = m, backward gradient = Q_bw(∇) routed to
  machine a's activation (custom_vjp below);
* the buffer itself may be stored in z bits (paper §H.5,
  "number of bits for previous messages").

``DirectQ`` (AC-GC / TinyScript style, the paper's baseline) and ``fp32``
(no compression) share the same interface.

All quantize/pack/unpack work routes through `repro.core.boundary`, the
backend-selectable fused boundary op (``backend="pallas"`` on TPU,
``"reference"`` jnp chain otherwise); the two backends are bit-identical
by contract, so ``backend`` never changes the trained model.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import boundary as B
from repro.core import quantization as Q


@dataclass(frozen=True)
class CompressionConfig:
    """Activation-boundary compression knobs (see README "Which knob
    do I turn"): the algorithm on the pipeline axis, code widths, the
    optional z-bit stored-message format, and the codec backend."""
    mode: str = "aqsgd"            # fp32 | directq | aqsgd
    fw_bits: int = 4               # forward activation bits
    bw_bits: int = 8               # backward activation-gradient bits
    buffer_bits: int = 0           # 0 = raw buffer; else z-bit stored (§H.5)
    buffer_dtype: str = "float32"  # raw-buffer storage dtype
    stochastic: bool = True
    backend: str = "auto"          # boundary op: reference | pallas | auto

    def with_(self, **kw):
        return dataclasses.replace(self, **kw)

    @property
    def compresses(self) -> bool:
        return self.mode != "fp32"

    def fw_wire_bytes(self, shape) -> int:
        if not self.compresses:
            return int(np.prod(shape)) * 4
        return Q.wire_bytes(shape, self.fw_bits)

    def bw_wire_bytes(self, shape) -> int:
        if not self.compresses:
            return int(np.prod(shape)) * 4
        return Q.wire_bytes(shape, self.bw_bits)


# ---------------------------------------------------------------------------
# message buffers
# ---------------------------------------------------------------------------

def init_buffers(cc: CompressionConfig, num_boundaries: int,
                 num_samples: int, seq: int, d: int) -> Optional[dict]:
    """Buffers for the whole dataset (AQ-SGD only)."""
    if cc.mode != "aqsgd":
        return None
    nb = num_boundaries
    bufs = {"seen": jnp.zeros((nb, num_samples), bool)}
    if cc.buffer_bits:
        pw = Q.packed_width(d, cc.buffer_bits)
        bufs["codes"] = jnp.zeros((nb, num_samples, seq, pw), jnp.uint8)
        bufs["scale"] = jnp.ones((nb, num_samples, seq, 1), jnp.float32)
    else:
        bufs["m"] = jnp.zeros((nb, num_samples, seq, d),
                              jnp.dtype(cc.buffer_dtype))
    return bufs


def buffer_nbytes(cc: CompressionConfig, num_boundaries: int,
                  num_samples: int, seq: int, d: int) -> int:
    """Storage cost of the message buffers (paper §3.3 / §G)."""
    if cc.mode != "aqsgd":
        return 0
    nb = num_boundaries
    if cc.buffer_bits:
        return nb * num_samples * seq * (Q.packed_width(d, cc.buffer_bits)
                                         + 4)
    return nb * num_samples * seq * d * jnp.dtype(cc.buffer_dtype).itemsize


def read_buffer(cc: CompressionConfig, bufs: dict, boundary: int,
                sample_ids: jax.Array, d: int) -> jax.Array:
    """-> m (B, S, d) float32 for the given samples."""
    if cc.buffer_bits:
        codes = bufs["codes"][boundary][sample_ids]
        scale = bufs["scale"][boundary][sample_ids]
        return B.decode(codes, scale, bits=cc.buffer_bits, d=d,
                        backend=cc.backend)
    return bufs["m"][boundary][sample_ids].astype(jnp.float32)


def write_buffer(cc: CompressionConfig, bufs: dict, boundary: int,
                 sample_ids: jax.Array, m_new: jax.Array) -> dict:
    """Store the updated messages for `sample_ids` at one boundary
    (raw dtype, or z-bit codes + scales when ``cc.buffer_bits``) and
    mark them seen — the write half of Algorithm 2's buffer state."""
    bufs = dict(bufs)
    if cc.buffer_bits:
        packed, scale = B.encode(m_new, bits=cc.buffer_bits,
                                 stochastic=False, backend=cc.backend)
        bufs["codes"] = bufs["codes"].at[boundary, sample_ids].set(packed)
        bufs["scale"] = bufs["scale"].at[boundary, sample_ids].set(scale)
    else:
        bufs["m"] = bufs["m"].at[boundary, sample_ids].set(
            m_new.astype(bufs["m"].dtype))
    bufs["seen"] = bufs["seen"].at[boundary, sample_ids].set(True)
    return bufs


# ---------------------------------------------------------------------------
# the boundary op (forward substitution + quantized backward gradient)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_ste(bw_bits: int, stochastic: bool, backend: str):
    """Straight-through boundary: forward value = message m, backward
    gradient = Q_bw(∇) (the paper quantizes the backward activation
    gradient directly — Algorithm 1 line 11).  The quantize→pack→unpack
    round trip runs inside this custom_vjp, so on the pallas backend the
    backward wire codec is fused too."""

    @jax.custom_vjp
    def ste(h, m_used, key):
        del h, key
        return m_used

    def fwd(h, m_used, key):
        del h
        return m_used, key

    def bwd(key, g):
        if bw_bits >= 32:
            gq = g
        else:
            gq = B.roundtrip(g, bits=bw_bits, stochastic=stochastic,
                             key=key, backend=backend)
        return (gq, jnp.zeros_like(g),
                np.zeros(key.shape, jax.dtypes.float0))

    ste.defvjp(fwd, bwd)
    return ste


def apply_boundary(cc: CompressionConfig, h: jax.Array, key: jax.Array,
                   m: Optional[jax.Array] = None,
                   seen: Optional[jax.Array] = None,
                   quantize_bw: bool = True):
    """One pipeline-boundary crossing.

    h: (B, S, d) activations leaving machine a (differentiable).
    m: (B, S, d) previous messages for these samples (aqsgd only).
    seen: (B,) first-visit mask.

    Returns (h_out, m_new):
      h_out — what machine b computes on (forward = message, backward =
              Q_bw(gradient) via the straight-through custom_vjp);
      m_new — updated messages to persist (None unless aqsgd).
    """
    kf, kb = jax.random.split(key)
    dtype = h.dtype
    backend = B.resolve_backend(cc.backend)
    h_sg = jax.lax.stop_gradient(h).astype(jnp.float32)

    if cc.mode == "fp32":
        return h, None
    if cc.mode == "directq":
        m_used = B.roundtrip(h_sg, bits=cc.fw_bits,
                             stochastic=cc.stochastic, key=kf,
                             backend=backend)
        m_new = None
    elif cc.mode == "aqsgd":
        assert m is not None and seen is not None
        _, _, m_upd = B.encode_delta(h_sg, m, bits=cc.fw_bits,
                                     stochastic=cc.stochastic, key=kf,
                                     backend=backend)
        m_used = jnp.where(seen[:, None, None], m_upd, h_sg)
        m_new = m_used
    else:
        raise ValueError(cc.mode)

    bw_bits = cc.bw_bits if quantize_bw else 32
    ste = _make_ste(bw_bits, cc.stochastic, backend)
    h_out = ste(h, m_used.astype(dtype), kb)
    return h_out, m_new
