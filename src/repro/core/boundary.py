"""Backend-selectable AQ-SGD boundary ops — the ONE hot path.

Every wire crossing in the system goes through the ops below, each
available on two bit-identical backends: the activation boundaries
(AQ-SGD sender/receiver, DirectQ, backward-gradient quantize, z-bit
buffer codec via `encode_delta`/`decode_accumulate`/`encode`/`decode`)
and the data-parallel gradient wire (`encode_with_scale`/`decode_codes`
/`decode_sum_mean` — the shared-scale compressed-allreduce codec behind
`core.grad_compress` and `core.collectives`):

* ``"pallas"``    — the fused TPU kernels in `repro.kernels.quant_pack`:
  one HBM pass per side instead of the ~6 round-trips of the unfused
  chain (paper §3.3's "compression is free" claim lives or dies here);
* ``"reference"`` — the pure-jnp chain over `repro.core.quantization`,
  kept as the correctness oracle and the fast path on CPU containers
  where Pallas only runs in interpret mode.

``"auto"`` (the default everywhere) resolves to pallas on TPU and
reference otherwise; REPRO_BOUNDARY_BACKEND overrides.  The contract
that the two backends are bit-identical — codes, scales, m_new, and
backward gradients — is enforced by tests/test_boundary_parity.py.

Stochastic rounding draws ONE uniform tensor here and feeds it to
either backend, so the wire payload and message buffers never depend on
the backend.  Scope note: the contract is per-op (same inputs -> same
bits).  Whole-model training trajectories may still drift at the ulp
level between backends, because swapping an opaque pallas_call for a
jnp chain changes how XLA fuses the SURROUNDING model ops — the same
class of drift as changing XLA versions, and statistically irrelevant
to convergence (fp32 runs are bit-equal; compressed runs track to
print precision — see the quickstart).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quantization as Q
from repro.kernels import ops as K

BACKENDS = ("reference", "pallas")
PACKABLE_BITS = (1, 2, 4, 8)       # dense byte-aligned wire packing
KERNEL_BITS = (2, 4, 8)            # widths the fused kernels implement


def resolve_backend(backend: str = "auto", bits: Optional[int] = None) \
        -> str:
    """'auto' -> REPRO_BOUNDARY_BACKEND, else pallas iff running on TPU
    (interpret-mode pallas on CPU is a debugging path, not a hot path).

    Widths outside KERNEL_BITS (the paper's fw3/bw6 ablations) always
    resolve to the reference chain — they are simulation-only."""
    if bits is not None and bits not in KERNEL_BITS:
        return "reference"
    if backend == "auto":
        env = os.environ.get("REPRO_BOUNDARY_BACKEND", "")
        if env:
            backend = env
        else:
            backend = "pallas" if jax.default_backend() == "tpu" \
                else "reference"
    assert backend in BACKENDS, backend
    return backend


def _noise(shape, stochastic: bool, key) -> Optional[jax.Array]:
    if not stochastic:
        return None
    if key is None:
        raise ValueError("stochastic boundary ops need a PRNG key")
    return jax.random.uniform(key, shape, jnp.float32)


def encode_delta(a, m, *, bits: int, stochastic: bool = False, key=None,
                 backend: str = "auto"):
    """AQ-SGD sender: (a, m) -> (packed u8 (..., pw), scale f32 (..., 1),
    m_new f32 (..., d)) with m_new = m + dequant(codes) — the wire
    payload plus the updated message buffer, in one fused pass.

    Non-byte-aligned widths (fw3/bw6 ablations) are simulation-only:
    payload is the raw u8 codes, never densely packed."""
    backend = resolve_backend(backend, bits)
    u = _noise(a.shape, stochastic, key)
    if backend == "pallas":
        return K.boundary_compress(a, m, u, bits=bits)
    a32 = a.astype(jnp.float32)
    m32 = m.astype(jnp.float32)
    codes, scale = Q.quantize(a32 - m32, bits, stochastic=stochastic,
                              noise=u)
    packed = Q.pack_codes(codes, bits) if bits in PACKABLE_BITS else codes
    m_new = m32 + Q.dequantize(codes, scale, bits)
    return packed, scale, m_new


def decode_accumulate(packed, scale, m, *, bits: int,
                      backend: str = "auto"):
    """AQ-SGD receiver: m_new f32 = m + dequant(unpack(packed)).  Applies
    the SAME quantized delta as the sender, so both buffer replicas stay
    bit-identical (Algorithm 2)."""
    backend = resolve_backend(backend, bits)
    if backend == "pallas":
        return K.boundary_decompress(packed, scale, m, bits=bits)
    d = m.shape[-1]
    codes = Q.unpack_codes(packed, bits, d) if bits in PACKABLE_BITS \
        else packed
    return m.astype(jnp.float32) + Q.dequantize(codes, scale, bits)


def encode(x, *, bits: int, stochastic: bool = False, key=None,
           backend: str = "auto"):
    """Direct quantize-and-pack: (packed u8 (..., pw), scale f32).  Used
    by the DirectQ sender, the backward-gradient wire, and z-bit buffer
    writes.  Non-byte-aligned widths return raw u8 codes (simulation
    only)."""
    backend = resolve_backend(backend, bits)
    u = _noise(x.shape, stochastic, key)
    if backend == "pallas":
        return K.quantize_pack(x, u, bits=bits)
    codes, scale = Q.quantize(x.astype(jnp.float32), bits,
                              stochastic=stochastic, noise=u)
    packed = Q.pack_codes(codes, bits) if bits in PACKABLE_BITS else codes
    return packed, scale


def decode(packed, scale, *, bits: int, d: int, dtype=jnp.float32,
           backend: str = "auto"):
    """Inverse of `encode`: (..., pw) u8 + scales -> (..., d) values."""
    backend = resolve_backend(backend, bits)
    if backend == "pallas":
        out = K.unpack_dequant(packed, scale, bits=bits, out_dtype=dtype)
        return out[..., :d]
    codes = Q.unpack_codes(packed, bits, d) if bits in PACKABLE_BITS \
        else packed
    return Q.dequantize(codes, scale, bits, dtype)


def encode_with_scale(x, scale, *, bits: int, stochastic: bool = False,
                      key=None, noise=None, backend: str = "auto"):
    """Quantize with a caller-supplied rowwise scale and pack: the DP
    gradient-wire sender.  In a compressed allreduce every worker
    quantizes against the SAME (pmax-shared) scale so that the psum of
    codes dequantizes to the exact mean; the scale is therefore an input
    here, never computed.  Returns packed u8 (..., pw) (raw u8 codes for
    non-byte-aligned widths, simulation only)."""
    backend = resolve_backend(backend, bits)
    # clamp once for BOTH backends: the pallas kernel clamps internally,
    # so an unclamped zero scale would NaN only the reference chain and
    # break the bit-identity contract
    scale = jnp.maximum(scale.astype(jnp.float32), Q._EPS)
    u = noise if noise is not None else _noise(x.shape, stochastic, key)
    if backend == "pallas":
        return K.quantize_pack_scaled(x, scale, u, bits=bits)
    codes, _ = Q.quantize(x.astype(jnp.float32), bits,
                          stochastic=stochastic, noise=u, scale=scale)
    return Q.pack_codes(codes, bits) if bits in PACKABLE_BITS else codes


def decode_codes(packed, *, bits: int, d: int, backend: str = "auto"):
    """Wire payload -> int32 codes: the accumulator form a compressed
    allreduce ships through ``psum`` (int32 sums of b-bit codes are
    exact in every reduction order, which is what makes the distributed
    wire bit-identical to the single-process simulation)."""
    backend = resolve_backend(backend, bits)
    if backend == "pallas":
        return K.unpack_codes(packed, bits=bits)[..., :d]
    codes = Q.unpack_codes(packed, bits, d) if bits in PACKABLE_BITS \
        else packed
    return codes.astype(jnp.int32)


def decode_sum_mean(total, scale, *, bits: int, n: int,
                    backend: str = "auto"):
    """Int32 code sum over n workers + shared rowwise scale -> mean
    values: the DP gradient-wire receiver.  n must be static (the mesh
    size).  Association mirrors `Q.dequantize` (2T - n*lv integer-exact,
    trailing divisions) so both backends round identically."""
    assert isinstance(n, int) and n >= 1, n
    backend = resolve_backend(backend, bits)
    if backend == "pallas":
        return K.dequant_sum_mean(total, scale, bits=bits, n=n)
    lv = (1 << bits) - 1
    ic = total.astype(jnp.float32) * 2.0 - float(n * lv)
    return ((ic * scale) / lv) / n


def roundtrip(x, *, bits: int, stochastic: bool = False, key=None,
              backend: str = "auto"):
    """encode -> decode in x.dtype: the wire-faithful fake quant used for
    backward gradients and DirectQ (== Q.qdq on the reference backend,
    fused on pallas)."""
    packed, scale = encode(x, bits=bits, stochastic=stochastic, key=key,
                           backend=backend)
    return decode(packed, scale, bits=bits, d=x.shape[-1], dtype=x.dtype,
                  backend=backend)
