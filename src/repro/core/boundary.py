"""Backend-selectable AQ-SGD boundary ops — the ONE hot path.

Every wire crossing in the system goes through the ops below, each
available on two bit-identical backends: the activation boundaries
(AQ-SGD sender/receiver, DirectQ, backward-gradient quantize, z-bit
buffer codec via `encode_delta`/`decode_accumulate`/`encode`/`decode`)
and the data-parallel gradient wire — the shared-scale
compressed-allreduce codec behind `core.grad_compress` and
`core.collectives`: `encode_codes_with_scale` (the ONE sender entry
point: int32 accumulator codes, plus the packed ring payload with
pack=True), `accumulate_codes` (the ring's fused unpack-accumulate),
`pack_sums`/`unpack_sums` (the ring's packed code-sum all-gather),
`decode_sum_mean` (the receiver), and the legacy
`encode_with_scale`/`decode_codes` pair:

* ``"pallas"``    — the fused TPU kernels in `repro.kernels.quant_pack`:
  one HBM pass per side instead of the ~6 round-trips of the unfused
  chain (paper §3.3's "compression is free" claim lives or dies here);
* ``"reference"`` — the pure-jnp chain over `repro.core.quantization`,
  kept as the correctness oracle and the fast path on CPU containers
  where Pallas only runs in interpret mode.

``"auto"`` (the default everywhere) resolves to pallas on TPU and
reference otherwise; REPRO_BOUNDARY_BACKEND overrides.  The contract
that the two backends are bit-identical — codes, scales, m_new, and
backward gradients — is enforced by tests/test_boundary_parity.py.

Stochastic rounding draws ONE uniform tensor here and feeds it to
either backend, so the wire payload and message buffers never depend on
the backend.  Scope note: the contract is per-op (same inputs -> same
bits).  Whole-model training trajectories may still drift at the ulp
level between backends, because swapping an opaque pallas_call for a
jnp chain changes how XLA fuses the SURROUNDING model ops — the same
class of drift as changing XLA versions, and statistically irrelevant
to convergence (fp32 runs are bit-equal; compressed runs track to
print precision — see the quickstart).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import env
from repro.core import quantization as Q
from repro.kernels import ops as K

BACKENDS = ("reference", "pallas")
PACKABLE_BITS = (1, 2, 4, 8)       # dense byte-aligned wire packing
KERNEL_BITS = (2, 4, 8)            # widths the fused kernels implement


def resolve_backend(backend: str = "auto", bits: Optional[int] = None) \
        -> str:
    """'auto' -> REPRO_BOUNDARY_BACKEND, else pallas iff running on TPU
    (interpret-mode pallas on CPU is a debugging path, not a hot path).

    Widths outside KERNEL_BITS (the paper's fw3/bw6 ablations) always
    resolve to the reference chain — they are simulation-only."""
    if bits is not None and bits not in KERNEL_BITS:
        return "reference"
    if backend == "auto":
        override = env.boundary_backend_override()
        if override:
            backend = override
        else:
            backend = "pallas" if jax.default_backend() == "tpu" \
                else "reference"
    assert backend in BACKENDS, backend
    return backend


def _noise(shape, stochastic: bool, key) -> Optional[jax.Array]:
    if not stochastic:
        return None
    if key is None:
        raise ValueError("stochastic boundary ops need a PRNG key")
    return jax.random.uniform(key, shape, jnp.float32)


def oncore_prng_enabled() -> bool:
    """REPRO_ONCORE_PRNG=1 opts the pallas encode kernels into drawing
    stochastic-rounding noise from the on-core PRNG instead of an HBM
    noise tensor.  TPU-only (interpret mode cannot lower prng_seed) and
    it relaxes the ref↔pallas parity contract to a STATISTICAL one —
    gated by the 10k-trial unbiasedness test in test_grad_compress.py."""
    return env.oncore_prng()


def _stochastic_args(shape, stochastic: bool, key, backend: str,
                     noise=None):
    """(noise tensor, on-core seed) for an encode op: exactly one is
    non-None when stochastic.  The seed path activates only for the
    pallas backend under the REPRO_ONCORE_PRNG opt-in."""
    if not stochastic:
        return None, None
    if noise is not None:
        return noise, None
    if backend == "pallas" and oncore_prng_enabled():
        if not K.oncore_prng_supported():
            raise ValueError(
                "REPRO_ONCORE_PRNG=1 but the on-core PRNG cannot lower "
                "on this backend (CPU interpret mode has no prng_seed); "
                "unset it or run on TPU")
        if key is None:
            raise ValueError("stochastic boundary ops need a PRNG key")
        k = jnp.asarray(key).reshape(-1)[-2:]
        return None, jax.lax.bitcast_convert_type(k, jnp.int32)
    return _noise(shape, stochastic, key), None


def encode_delta(a, m, *, bits: int, stochastic: bool = False, key=None,
                 backend: str = "auto"):
    """AQ-SGD sender: (a, m) -> (packed u8 (..., pw), scale f32 (..., 1),
    m_new f32 (..., d)) with m_new = m + dequant(codes) — the wire
    payload plus the updated message buffer, in one fused pass.

    Non-byte-aligned widths (fw3/bw6 ablations) are simulation-only:
    payload is the raw u8 codes, never densely packed."""
    backend = resolve_backend(backend, bits)
    u, seed = _stochastic_args(a.shape, stochastic, key, backend)
    if backend == "pallas":
        return K.boundary_compress(a, m, u, bits=bits, seed=seed)
    a32 = a.astype(jnp.float32)
    m32 = m.astype(jnp.float32)
    codes, scale = Q.quantize(a32 - m32, bits, stochastic=stochastic,
                              noise=u)
    packed = Q.pack_codes(codes, bits) if bits in PACKABLE_BITS else codes
    m_new = m32 + Q.dequantize(codes, scale, bits)
    return packed, scale, m_new


def decode_accumulate(packed, scale, m, *, bits: int,
                      backend: str = "auto"):
    """AQ-SGD receiver: m_new f32 = m + dequant(unpack(packed)).  Applies
    the SAME quantized delta as the sender, so both buffer replicas stay
    bit-identical (Algorithm 2)."""
    backend = resolve_backend(backend, bits)
    if backend == "pallas":
        return K.boundary_decompress(packed, scale, m, bits=bits)
    d = m.shape[-1]
    codes = Q.unpack_codes(packed, bits, d) if bits in PACKABLE_BITS \
        else packed
    return m.astype(jnp.float32) + Q.dequantize(codes, scale, bits)


def encode(x, *, bits: int, stochastic: bool = False, key=None,
           backend: str = "auto"):
    """Direct quantize-and-pack: (packed u8 (..., pw), scale f32).  Used
    by the DirectQ sender, the backward-gradient wire, and z-bit buffer
    writes.  Non-byte-aligned widths return raw u8 codes (simulation
    only)."""
    backend = resolve_backend(backend, bits)
    u, seed = _stochastic_args(x.shape, stochastic, key, backend)
    if backend == "pallas":
        return K.quantize_pack(x, u, bits=bits, seed=seed)
    codes, scale = Q.quantize(x.astype(jnp.float32), bits,
                              stochastic=stochastic, noise=u)
    packed = Q.pack_codes(codes, bits) if bits in PACKABLE_BITS else codes
    return packed, scale


def decode(packed, scale, *, bits: int, d: int, dtype=jnp.float32,
           backend: str = "auto"):
    """Inverse of `encode`: (..., pw) u8 + scales -> (..., d) values."""
    backend = resolve_backend(backend, bits)
    if backend == "pallas":
        out = K.unpack_dequant(packed, scale, bits=bits, out_dtype=dtype)
        return out[..., :d]
    codes = Q.unpack_codes(packed, bits, d) if bits in PACKABLE_BITS \
        else packed
    return Q.dequantize(codes, scale, bits, dtype)


def encode_with_scale(x, scale, *, bits: int, stochastic: bool = False,
                      key=None, noise=None, backend: str = "auto"):
    """Quantize with a caller-supplied rowwise scale and pack: the DP
    gradient-wire sender.  In a compressed allreduce every worker
    quantizes against the SAME (pmax-shared) scale so that the psum of
    codes dequantizes to the exact mean; the scale is therefore an input
    here, never computed.  Returns packed u8 (..., pw) (raw u8 codes for
    non-byte-aligned widths, simulation only)."""
    backend = resolve_backend(backend, bits)
    # clamp once for BOTH backends: the pallas kernel clamps internally,
    # so an unclamped zero scale would NaN only the reference chain and
    # break the bit-identity contract
    scale = jnp.maximum(scale.astype(jnp.float32), Q._EPS)
    u = noise if noise is not None else _noise(x.shape, stochastic, key)
    if backend == "pallas":
        return K.quantize_pack_scaled(x, scale, u, bits=bits)
    codes, _ = Q.quantize(x.astype(jnp.float32), bits,
                          stochastic=stochastic, noise=u, scale=scale)
    return Q.pack_codes(codes, bits) if bits in PACKABLE_BITS else codes


def decode_codes(packed, *, bits: int, d: int, backend: str = "auto"):
    """Wire payload -> int32 codes: the accumulator form a compressed
    allreduce ships through ``psum`` (int32 sums of b-bit codes are
    exact in every reduction order, which is what makes the distributed
    wire bit-identical to the single-process simulation)."""
    backend = resolve_backend(backend, bits)
    if backend == "pallas":
        return K.unpack_codes(packed, bits=bits)[..., :d]
    codes = Q.unpack_codes(packed, bits, d) if bits in PACKABLE_BITS \
        else packed
    return codes.astype(jnp.int32)


def decode_sum_mean(total, scale, *, bits: int, n: int,
                    backend: str = "auto"):
    """Int32 code sum over n workers + shared rowwise scale -> mean
    values: the DP gradient-wire receiver.  n must be static (the mesh
    size).  Association mirrors `Q.dequantize` (2T - n*lv integer-exact,
    trailing divisions) so both backends round identically."""
    assert isinstance(n, int) and n >= 1, n
    backend = resolve_backend(backend, bits)
    if backend == "pallas":
        return K.dequant_sum_mean(total, scale, bits=bits, n=n)
    lv = (1 << bits) - 1
    ic = total.astype(jnp.float32) * 2.0 - float(n * lv)
    return ((ic * scale) / lv) / n


def encode_codes_with_scale(x, scale, *, bits: int, stochastic: bool = False,
                            key=None, noise=None, pack: bool = False,
                            backend: str = "auto"):
    """Codes-only encode against a caller-supplied rowwise scale: the ONE
    sender entry point of the compressed DP allreduce (psum wire, ring
    wire, and the simulator all route here).

    Returns int32 codes (..., d) — the accumulator form — without the
    on-device pack→unpack round trip the old `encode_with_scale` +
    `decode_codes` pair paid.  pack=True additionally emits the packed
    u8 wire payload in the SAME fused pass: (packed, codes) — that is
    the ring sender, whose packed segments genuinely ship.

    Non-byte-aligned widths (simulation-only) return raw u8 codes as
    the payload when pack=True."""
    backend = resolve_backend(backend, bits)
    scale = jnp.maximum(scale.astype(jnp.float32), Q._EPS)
    u, seed = _stochastic_args(x.shape, stochastic, key, backend,
                               noise=noise)
    if backend == "pallas":
        return K.quantize_codes_scaled(x, scale, u, bits=bits, pack=pack,
                                       seed=seed)
    codes, _ = Q.quantize(x.astype(jnp.float32), bits,
                          stochastic=stochastic, noise=u, scale=scale)
    icodes = codes.astype(jnp.int32)
    if pack:
        packed = Q.pack_codes(codes, bits) if bits in PACKABLE_BITS \
            else codes
        return packed, icodes
    return icodes


def accumulate_codes(packed, acc, *, bits: int, backend: str = "auto"):
    """Ring accumulate step: acc + unpack(packed) in one fused int32
    pass — the local accumulation that replaces the psum's i32 lanes
    (int32 adds are exact in any order, which is what keeps the ring
    bit-identical to `psum(codes)`)."""
    backend = resolve_backend(backend, bits)
    if backend == "pallas":
        return K.unpack_accumulate(packed, acc, bits=bits)
    d = acc.shape[-1]
    codes = Q.unpack_codes(packed, bits, d) if bits in PACKABLE_BITS \
        else packed
    return acc + codes.astype(jnp.int32)


def pack_sums(total, *, bits: int, n: int, backend: str = "auto"):
    """Pack int32 code sums over n workers densely at
    `Q.sum_wire_bits(bits, n)` bits — the ring's all-gather payload.
    (b + ceil(log2 n) bits per element is the exactness price: shipping
    sums keeps the ring bit-identical to the psum wire, where
    re-quantizing the mean to b bits would not.)"""
    backend = resolve_backend(backend, bits)
    if backend == "pallas":
        return K.pack_sums(total, bits=bits, n=n)
    return Q.pack_sums(total, bits, n)


def unpack_sums(packed, *, bits: int, n: int, d: int,
                backend: str = "auto"):
    """Inverse of `pack_sums`: u8 payload -> (..., d) int32 code sums."""
    backend = resolve_backend(backend, bits)
    if backend == "pallas":
        return K.unpack_sums(packed, bits=bits, n=n)[..., :d]
    return Q.unpack_sums(packed, bits, n, d)


def roundtrip(x, *, bits: int, stochastic: bool = False, key=None,
              backend: str = "auto"):
    """encode -> decode in x.dtype: the wire-faithful fake quant used for
    backward gradients and DirectQ (== Q.qdq on the reference backend,
    fused on pallas)."""
    packed, scale = encode(x, bits=bits, stochastic=stochastic, key=key,
                           backend=backend)
    return decode(packed, scale, bits=bits, d=x.shape[-1], dtype=x.dtype,
                  backend=backend)
