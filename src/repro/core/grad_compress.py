"""Bucketed error-feedback gradient compression for the data-parallel axis.

The paper's §4.3 combines AQ-SGD with QuantizedAdam (Tang et al. 2021) —
an error-compensated low-bit compressor on *model gradients* — to get
"end-to-end communication compression" (Fig. 5).  Per worker i:

    v_i  = g_i + e_i               (compensate with carried error)
    s    = max_i rowmax|v_i|       (shared scale: pmax on the wire)
    c_i  = quantize(v_i, s)        (b-bit codes, stochastic)
    e_i' = v_i - dequant(c_i, s)   (new carried error)
    ḡ   = dequant(Σ_i c_i, s)/n   (wire: packed codes; psum in int32)

Quantization is linear given the shared scale, so the code-domain psum
dequantizes to the exact mean of the quantized values, and int32 code
sums are exact in every reduction order — which is what makes the
distributed wire (`core.collectives.ef_psum_mean_bucket`, run inside
``shard_map``) bit-identical to `compress_allreduce` here.

Wire layout: the whole gradient tree is flattened and concatenated into
ONE zero-padded (rows, group_d) bucket (`BucketLayout`), so scale groups
are always `group_d` wide regardless of leaf shapes — a (4096, 2) leaf
no longer quantizes per-row with degenerate 2-element scale groups — and
every pass runs through the fused `core.boundary` codec
(`encode_codes_with_scale` / `decode_sum_mean`): one HBM pass per side,
no per-leaf Python loop, no unfused `Q.qdq`, and no on-device
pack→unpack round trip — the codes-only encode IS the accumulator form
(the ring wire asks the same pass for the packed payload too).

Error-feedback state is the same (rows, group_d) f32 bucket, carried per
worker across steps.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import boundary as B
from repro.core.quantization import _EPS

DEFAULT_GROUP_D = 512          # scale-group width (bucket columns)


def ring_segment_rows(rows: int, n: int) -> int:
    """Rows per ring segment for an n-device ring over a rows-row
    bucket: ceil(rows / n).  The last segment is ragged and zero-padded
    to this width; every wire, simulator, state layout, and byte model
    that cuts the bucket derives the segment width HERE (re-exported as
    `collectives.ring_segment_rows`) so they cannot drift."""
    return -(-rows // max(n, 1))


# ---------------------------------------------------------------------------
# bucket layout: gradient tree <-> one padded (rows, group_d) array
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BucketLayout:
    """Static description of the flatten-and-concat gradient bucket."""
    sizes: tuple          # element count per leaf, tree-flatten order
    shapes: tuple         # leaf shapes
    rows: int             # bucket rows (ceil(total / group_d))
    group_d: int          # scale-group width
    pad: int              # trailing zeros filling the last row

    @property
    def total(self) -> int:
        return self.rows * self.group_d - self.pad


def bucket_layout(tree, group_d: int = DEFAULT_GROUP_D) -> BucketLayout:
    """Layout for a gradient pytree (arrays or ShapeDtypeStructs)."""
    leaves = jax.tree.leaves(tree)
    sizes = tuple(int(np.prod(leaf.shape)) for leaf in leaves)
    total = sum(sizes)
    rows = max(-(-total // group_d), 1)
    return BucketLayout(sizes=sizes,
                        shapes=tuple(tuple(leaf.shape) for leaf in leaves),
                        rows=rows, group_d=group_d,
                        pad=rows * group_d - total)


def flatten_bucket(tree, layout: BucketLayout) -> jax.Array:
    """Gradient tree -> f32 (rows, group_d) bucket (zero-padded tail)."""
    flat = jnp.concatenate(
        [leaf.astype(jnp.float32).reshape(-1)
         for leaf in jax.tree.leaves(tree)])
    if layout.pad:
        flat = jnp.pad(flat, (0, layout.pad))
    return flat.reshape(layout.rows, layout.group_d)


def unflatten_bucket(bucket: jax.Array, layout: BucketLayout, like):
    """Inverse of `flatten_bucket`; restores shapes and dtypes of `like`."""
    flat = bucket.reshape(-1)[:layout.total]
    leaves, treedef = jax.tree.flatten(like)
    offs = np.cumsum((0,) + layout.sizes)
    out = [flat[offs[i]:offs[i + 1]].reshape(layout.shapes[i])
           .astype(leaves[i].dtype) for i in range(len(leaves))]
    return jax.tree.unflatten(treedef, out)


def init_error_state(params, group_d: int = DEFAULT_GROUP_D) -> jax.Array:
    """Per-worker carried-error bucket, zeros (rows, group_d) f32."""
    lay = bucket_layout(params, group_d)
    return jnp.zeros((lay.rows, lay.group_d), jnp.float32)


# ---------------------------------------------------------------------------
# the shared codec math (one definition for wire and simulation)
# ---------------------------------------------------------------------------

def local_scale(v: jax.Array) -> jax.Array:
    """Rowwise absmax of a compensated bucket — the quantity the wire
    reduces with ``pmax`` to form the shared scale."""
    return jnp.max(jnp.abs(v), axis=-1, keepdims=True)


def ef_encode(v: jax.Array, scale: jax.Array, bits: int, key,
              *, stochastic: bool = True, backend: str = "auto",
              pack: bool = False):
    """One worker's sender side: (compensated bucket, shared scale) ->
    (packed payload | None, int32 codes, new carried error).

    The codes-only encode (`B.encode_codes_with_scale`) is the ONE
    entry point every wire shares: the psum wire and the simulator take
    the codes straight to their accumulator (no on-device pack→unpack
    round trip), the ring passes pack=True so the same fused pass also
    emits the packed segments that ship on the ppermute hops.  The new
    error is v - dequant(codes) via `decode_sum_mean` with n=1 (an
    exact /1, so bit-identical to the old packed round trip)."""
    out = B.encode_codes_with_scale(v, scale, bits=bits,
                                    stochastic=stochastic, key=key,
                                    pack=pack, backend=backend)
    packed, codes = out if pack else (None, out)
    q = B.decode_sum_mean(codes, scale, bits=bits, n=1, backend=backend)
    return packed, codes, v - q


def worker_key(key, i):
    """Per-worker noise key; the wire uses fold_in(key, axis_index) so
    simulated worker i and mesh position i draw identical noise."""
    return jax.random.fold_in(key, i)


# ---------------------------------------------------------------------------
# single-worker form: error-feedback compress (trivial allreduce)
# ---------------------------------------------------------------------------

def compress_gradients(grads, error_state, bits: int, key,
                       stochastic: bool = True, *, backend: str = "auto",
                       layout: BucketLayout | None = None):
    """Error-feedback compress one gradient tree through the bucketed
    fused codec (the n=1 wire: quantize, dequantize, carry the error).

    error_state: (rows, group_d) f32 from `init_error_state`.
    Returns (compressed_grads, new_error_state)."""
    lay = layout or bucket_layout(grads)
    v = flatten_bucket(grads, lay) + error_state
    scale = jnp.maximum(local_scale(v), _EPS)
    _, _, new_err = ef_encode(v, scale, bits, worker_key(key, 0),
                              stochastic=stochastic, backend=backend)
    q = v - new_err
    return unflatten_bucket(q, lay, grads), new_err


# ---------------------------------------------------------------------------
# multi-worker simulation, bit-faithful to the shard_map wire
# ---------------------------------------------------------------------------

def compress_allreduce(grads_list, error_state, bits: int, key,
                       *, stochastic: bool = True, backend: str = "auto",
                       layout: BucketLayout | None = None):
    """Simulate the compressed DP allreduce over n workers.

    grads_list: one gradient tree per worker; error_state: stacked
    (n, rows, group_d) f32.  Returns (mean_grads tree, new error stack).

    Bit-identical to `core.collectives.ef_psum_mean_bucket` run on an
    n-device mesh with the same base key: the shared scale is an
    order-independent f32 max, the code accumulation is an exact int32
    sum, and both routes end in the same `decode_sum_mean`."""
    n = len(grads_list)
    lay = layout or bucket_layout(grads_list[0])
    v = jnp.stack([flatten_bucket(g, lay) for g in grads_list]) \
        + error_state
    scale = jnp.maximum(jnp.max(local_scale(v), axis=0), _EPS)
    new_err = []
    total = None
    for i in range(n):
        _, codes, e = ef_encode(v[i], scale, bits, worker_key(key, i),
                                stochastic=stochastic, backend=backend)
        total = codes if total is None else total + codes
        new_err.append(e)
    mean = B.decode_sum_mean(total, scale, bits=bits, n=n, backend=backend)
    return (unflatten_bucket(mean, lay, grads_list[0]),
            jnp.stack(new_err))


def compress_reduce_scatter(grads_list, error_state, bits: int, key,
                            *, stochastic: bool = True,
                            backend: str = "auto",
                            layout: BucketLayout | None = None):
    """Simulate the ZeRO-sharded compressed reduce-scatter over n
    workers: the allreduce stopped at the segment midpoint.

    Same encode as `compress_allreduce` (identical codes, scales, and
    error states), but instead of every worker recovering the full mean
    bucket, worker i keeps only its OWN segment's mean — the regime of
    `core.collectives.ring_ef_reduce_scatter_bucket`, to which this is
    bit-identical on the same per-worker inputs (the owned segment's
    int32 code sum is exact in any reduction order).

    Returns (segment means (n, seg, group_d) with
    seg = ceil(rows / n), new error stack (n, rows, group_d)).  Rows of
    a ragged last segment beyond the bucket are decoded against a ZERO
    scale — zero codes, zero scale, sign-preserving zero mean — exactly
    as the wire decodes them; callers must drop them before parameters
    (`unflatten_bucket` on the reassembled bucket does)."""
    n = len(grads_list)
    lay = layout or bucket_layout(grads_list[0])
    v = jnp.stack([flatten_bucket(g, lay) for g in grads_list]) \
        + error_state
    scale = jnp.maximum(jnp.max(local_scale(v), axis=0), _EPS)
    new_err = []
    total = None
    for i in range(n):
        _, codes, e = ef_encode(v[i], scale, bits, worker_key(key, i),
                                stochastic=stochastic, backend=backend)
        total = codes if total is None else total + codes
        new_err.append(e)
    seg = ring_segment_rows(lay.rows, n)
    pad = seg * n - lay.rows
    if pad:
        total = jnp.pad(total, ((0, pad), (0, 0)))
        scale = jnp.pad(scale, ((0, pad), (0, 0)))
    means = B.decode_sum_mean(total, scale, bits=bits, n=n,
                              backend=backend)
    return means.reshape(n, seg, lay.group_d), jnp.stack(new_err)


# ---------------------------------------------------------------------------
# wire accounting
# ---------------------------------------------------------------------------

def grad_wire_bytes(params, bits: int,
                    group_d: int = DEFAULT_GROUP_D) -> int:
    """Bytes on the DP wire per worker per step with b-bit compression:
    one packed bucket + one f32 scale per `group_d` group (the bucketed
    layout amortizes scales over fixed-width groups, so small-last-dim
    leaves no longer pay one scale per tiny row)."""
    from repro.core import quantization as Q
    lay = bucket_layout(params, group_d)
    return Q.wire_bytes((lay.rows, lay.group_d), bits)
