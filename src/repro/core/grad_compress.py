"""Error-feedback gradient compression for the data-parallel axis.

The paper's §4.3 combines AQ-SGD with QuantizedAdam (Tang et al. 2021) —
an error-compensated low-bit compressor on *model gradients* — to get
"end-to-end communication compression" (Fig. 5).  We implement the same
error-feedback scheme:

    v   = g + e                (compensate with carried error)
    q   = Q_b(v)               (unbiased uniform quantization)
    e'  = v - q                (new carried error)
    ḡ  = allreduce_mean(q)    (wire carries packed codes + scales)

On a mesh the allreduce is a ``psum`` of int32-accumulated codes (see
training/pipeline.py); in single-process simulation it is the identity /
a mean over simulated workers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantization as Q


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def _leaf_qdq(g, e, bits, key, stochastic):
    v = g.astype(jnp.float32) + e
    flat = v.reshape(-1, v.shape[-1]) if v.ndim > 1 else v.reshape(1, -1)
    q = Q.qdq(flat, bits, stochastic=stochastic, key=key).reshape(v.shape)
    return q, v - q


def compress_gradients(grads, error_state, bits: int, key,
                       stochastic: bool = True):
    """Error-feedback compress each gradient leaf.

    Returns (compressed_grads, new_error_state)."""
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = treedef.flatten_up_to(error_state)
    keys = jax.random.split(key, len(leaves))
    out, errs = [], []
    for g, e, k in zip(leaves, err_leaves, keys):
        q, ne = _leaf_qdq(g, e, bits, k, stochastic)
        out.append(q.astype(g.dtype))
        errs.append(ne)
    return treedef.unflatten(out), treedef.unflatten(errs)


def grad_wire_bytes(params, bits: int) -> int:
    """Bytes on the DP wire per worker per step with b-bit compression."""
    total = 0
    for p in jax.tree.leaves(params):
        shape = p.shape if p.ndim > 1 else (1, max(p.size, 1))
        total += Q.wire_bytes(shape, bits)
    return total
