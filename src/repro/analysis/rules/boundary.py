"""Rule ``no-unfused-quantize``: the fused-boundary invariant.

Every quantize / pack / unpack / dequantize on a wire path must route
through `repro.core.boundary`'s backend-selectable fused ops — never
the raw `repro.core.quantization` building blocks, whose unfused
quantize->pack chain costs ~6 HBM round trips per crossing.  This is
the rule form of the `inspect.getsource` scans PR 1/PR 2 kept in
``tests/test_boundary_parity.py`` and ``tests/test_grad_compress.py``
— consolidated here, alias-proof (``from repro.core import
quantization as QQ`` is caught), and enforced over every wire-path
module at once instead of a hand-kept module list."""
from __future__ import annotations

import ast

from repro.analysis.lint import dotted, imported_names, in_dirs, \
    module_aliases, rule

QUANT_MODULE = "repro.core.quantization"
BANNED = ("quantize", "pack_codes", "unpack_codes", "dequantize", "qdq")

# the wire-path modules: trainers, collectives, comm subsystem, serving.
# core/boundary.py IS the fused implementation and core/quantization.py
# the building blocks themselves; kernels/ and optim/ (HBM-local 8-bit
# optimizer state) are off the wire path.
_SCOPE = in_dirs(
    "src/repro/training/", "src/repro/comm/", "src/repro/serving/",
    "src/repro/core/",
    exclude=("src/repro/core/boundary.py",
             "src/repro/core/quantization.py"))


@rule("no-unfused-quantize",
      summary="wire-path modules must use core.boundary fused ops, "
              "never raw core.quantization calls",
      rationale="the unfused quantize->pack chain costs ~6 HBM round "
                "trips per boundary crossing and dodges the "
                "ref|pallas parity gates on core.boundary",
      fix_hint="route the crossing through the matching "
               "repro.core.boundary op (encode_delta, "
               "encode_codes_with_scale, decode_sum_mean, ...)",
      applies=_SCOPE)
def check(ctx):
    """Flag calls to banned `quantization` functions via any module
    alias or direct from-import."""
    aliases = module_aliases(ctx.tree, QUANT_MODULE)
    direct = {local for local, orig
              in imported_names(ctx.tree, QUANT_MODULE).items()
              if orig in BANNED}
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr in BANNED \
                and dotted(f.value) in aliases:
            yield node.lineno, (
                f"unfused `{dotted(f)}(...)` on a wire path")
        elif isinstance(f, ast.Name) and f.id in direct:
            yield node.lineno, (
                f"unfused `{f.id}(...)` (imported from "
                f"{QUANT_MODULE}) on a wire path")
