"""Built-in lint rules, one module per concern.

Importing this package registers every rule with the engine
(`repro.analysis.lint`); adding a rule = adding/extending one module
here and importing it below (docs/ANALYSIS.md walks through it).  The
rule catalog — what each id guards and why — is generated into the
``--json`` report from the rule metadata, so it cannot drift from the
code."""
from repro.analysis.rules import (  # noqa: F401  (self-registering)
    boundary,
    comm,
    dtypes,
    envreads,
    imports,
    jit,
    sourcescan,
)
