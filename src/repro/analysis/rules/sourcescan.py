"""Rule ``no-getsource-scan``: invariants are lint rules, not regexes.

The PR-1..4 era enforced source invariants with per-test
``inspect.getsource`` substring scans — each one a hand-kept module
list that silently went stale when code moved (the PR-4 bucket-
doubling bug lived exactly in such a blind spot).  Those scans are now
`repro.analysis` rules; this meta-rule keeps new ones from sneaking
back in."""
from __future__ import annotations

import ast

from repro.analysis.lint import dotted, imported_names, in_dirs, \
    module_aliases, rule


@rule("no-getsource-scan",
      summary="no inspect.getsource source-scanning in tests or src",
      rationale="getsource substring scans carry hand-kept module "
                "lists that go stale silently; the lint engine scopes "
                "rules by path and survives refactors",
      fix_hint="write a repro.analysis.rules rule and assert "
               "run_rule(<id>) == [] (see docs/ANALYSIS.md)",
      applies=in_dirs("src/", "tests/"))
def check(ctx):
    """Flag ``inspect.getsource(...)`` calls under any alias."""
    inspect_names = module_aliases(ctx.tree, "inspect")
    direct = {local for local, orig
              in imported_names(ctx.tree, "inspect").items()
              if orig == "getsource"}
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        if name is None:
            continue
        head, _, fn = name.rpartition(".")
        if (head in inspect_names and fn == "getsource") \
                or (not head and fn in direct):
            yield node.lineno, ("inspect.getsource source scan — "
                                "write a lint rule instead")
