"""Rule ``no-stray-env-read``: every ``REPRO_*`` knob goes through
`repro.env`.

`repro.env.KNOBS` is the single documented switchboard (check_docs
cross-checks it against the README reference); a stray
``os.environ.get("REPRO_X")`` elsewhere is an undocumented knob.  This
is the AST form of the regex scan ``tools/check_docs.py`` used to run
— with the regex's blind spot fixed: aliased imports (``from os import
environ as e``, ``from os import getenv as g``, ``import os as o``)
are resolved instead of missed."""
from __future__ import annotations

import ast

from repro.analysis.lint import const_str, dotted, in_dirs, \
    module_aliases, rule

# src + tools + benchmarks + examples; tests may probe knobs freely,
# and src/repro/env.py IS the accessor module.
_SCOPE = in_dirs("src/", "tools/", "benchmarks/", "examples/",
                 exclude=("src/repro/env.py",))


def _is_repro(node) -> bool:
    s = const_str(node)
    return s is not None and s.startswith("REPRO_")


@rule("no-stray-env-read",
      summary="REPRO_* environment knobs are read only by "
              "src/repro/env.py",
      rationale="repro.env.KNOBS is the documented knob table the "
                "README reference is gated against; a stray read is "
                "an undocumented switch",
      fix_hint="add an accessor to repro/env.py (and its KNOBS row) "
               "and call that",
      applies=_SCOPE)
def check(ctx):
    """Flag REPRO_* reads through ``os.environ`` / ``os.getenv`` under
    any import alias: subscripts, ``.get``/``.setdefault`` calls, and
    bare ``getenv`` from-imports."""
    os_names = module_aliases(ctx.tree, "os")
    environ_names = module_aliases(ctx.tree, "os.environ") \
        | {f"{o}.environ" for o in os_names}
    getenv_names = module_aliases(ctx.tree, "os.getenv") \
        | {f"{o}.getenv" for o in os_names}
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Subscript) \
                and isinstance(node.ctx, ast.Load) \
                and dotted(node.value) in environ_names \
                and _is_repro(node.slice):
            yield node.lineno, ("REPRO_* read via os.environ[...] "
                                "outside repro/env.py")
        elif isinstance(node, ast.Call) and node.args:
            name = dotted(node.func)
            if name is None:
                continue
            if name in getenv_names and _is_repro(node.args[0]):
                yield node.lineno, ("REPRO_* read via os.getenv "
                                    "outside repro/env.py")
            elif name.endswith((".get", ".setdefault")) \
                    and name.rsplit(".", 1)[0] in environ_names \
                    and _is_repro(node.args[0]):
                yield node.lineno, ("REPRO_* read via os.environ.get "
                                    "outside repro/env.py")
