"""Comm-subsystem rules: the registry is the only door to the wire.

Three rules guard `repro.comm`'s ownership of every inter-machine
byte:

* ``no-legacy-comm-kwargs`` — the pre-registry scattered kwargs on
  ``PipelineConfig`` / ``SimTrainConfig`` raise at runtime since PR 6;
  any call site still passing one is dead code that only detonates
  when executed.
* ``registry-completeness`` — a ``register_wire`` call must carry its
  ``wire_bytes`` byte model, and a real collective wire must carry its
  simulator mirror AND its expected-collective manifest (the HLO
  auditor's per-wire contract); harness-internal wrappers
  (``internal=True``) are exempt from the manifest.
* ``no-direct-collective`` — ``jax.lax`` collectives live only in the
  comm-owned modules; anywhere else they are bytes the registry cannot
  account for.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import dotted, imported_names, in_dirs, \
    module_aliases, rule

LEGACY_KWARGS = ("compression", "buffer_bits", "dp_grad_bits",
                 "dp_grad_group", "dp_wire", "dp_sharded")
_CONFIG_CLASSES = ("PipelineConfig", "SimTrainConfig")


@rule("no-legacy-comm-kwargs",
      summary="no call site passes the removed pre-registry comm "
              "kwargs to PipelineConfig / SimTrainConfig",
      rationale="those kwargs raise a migration TypeError at runtime "
                "(PR 6); a surviving call site is a landmine that "
                "only detonates when executed",
      fix_hint="pass comm=CommConfig(...) — CommConfig.from_legacy "
               "converts the old knob set verbatim")
def check_legacy(ctx):
    """Flag PipelineConfig(...)/SimTrainConfig(...) calls carrying any
    removed comm kwarg (CommConfig.from_legacy is NOT flagged — it is
    the supported converter)."""
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        if name is None or name.split(".")[-1] not in _CONFIG_CLASSES:
            continue
        bad = [kw.arg for kw in node.keywords if kw.arg in LEGACY_KWARGS]
        if bad:
            yield node.lineno, (
                f"removed comm kwarg(s) {', '.join(bad)} passed to "
                f"{name.split('.')[-1]} — this raises at runtime")


@rule("registry-completeness",
      summary="every register_wire call provides wire_bytes, and a "
              "collective wire its sim_allreduce + "
              "expected_collectives manifest",
      rationale="a wire without a byte model dodges the HLO byte "
                "regression; one without a manifest dodges the "
                "collective auditor — the gates that make every perf "
                "claim checkable",
      fix_hint="pass wire_bytes=..., and for collective wires "
               "sim_allreduce=... plus expected_collectives=... "
               "(internal=True harness wrappers skip the manifest)",
      applies=in_dirs("src/"))
def check_registry(ctx):
    """Statically require the registry-enrollment kwargs on every
    ``register_wire`` call site (splatted ``**kwargs`` calls cannot be
    checked and are flagged as unverifiable)."""
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        if name is None or name.split(".")[-1] != "register_wire":
            continue
        kwargs = {kw.arg for kw in node.keywords}
        if None in kwargs:
            yield node.lineno, ("register_wire call splats **kwargs — "
                                "enrollment cannot be verified "
                                "statically")
            continue
        if "wire_bytes" not in kwargs:
            yield node.lineno, ("register_wire without a wire_bytes= "
                                "byte model")
        if "collective" in kwargs:
            if "sim_allreduce" not in kwargs:
                yield node.lineno, ("collective wire registered "
                                    "without its sim_allreduce= "
                                    "simulator mirror")
            internal = any(
                kw.arg == "internal"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value is True for kw in node.keywords)
            if not internal and "expected_collectives" not in kwargs:
                yield node.lineno, ("collective wire registered "
                                    "without an expected_collectives= "
                                    "manifest for the HLO auditor")


COLLECTIVE_FNS = ("psum", "pmean", "pmax", "pmin", "ppermute",
                  "all_gather", "psum_scatter", "all_to_all",
                  "pbroadcast")

# the comm-owned modules: the collectives library, the registry + fault
# wrappers, the pipeline trainer (activation ppermute), the mesh shim,
# and expert-parallel MoE dispatch.
_COLL_SCOPE = in_dirs(
    "src/",
    exclude=("src/repro/core/collectives.py", "src/repro/comm/wires.py",
             "src/repro/comm/faults.py",
             "src/repro/training/pipeline.py",
             "src/repro/launch/mesh.py", "src/repro/models/moe.py"))


@rule("no-direct-collective",
      summary="jax.lax collectives appear only in comm-owned modules",
      rationale="a collective outside core/collectives, comm/, the "
                "pipeline trainer or moe dispatch ships bytes the "
                "wire registry cannot account for (the PR-4 hidden-"
                "collective bug class, hand-written)",
      fix_hint="move the collective into core/collectives.py or "
               "register it as a wire; consumers go through the "
               "registry",
      applies=_COLL_SCOPE)
def check_collectives(ctx):
    """Flag ``jax.lax.<collective>`` calls under any alias of ``jax``
    / ``jax.lax``, and direct ``from jax.lax import psum`` uses."""
    jax_names = module_aliases(ctx.tree, "jax")
    lax_names = module_aliases(ctx.tree, "jax.lax") \
        | {f"{j}.lax" for j in jax_names}
    direct = {local for local, orig
              in imported_names(ctx.tree, "jax.lax").items()
              if orig in COLLECTIVE_FNS}
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        if name is None:
            continue
        head, _, fn = name.rpartition(".")
        if (head in lax_names and fn in COLLECTIVE_FNS) \
                or (not head and fn in direct):
            yield node.lineno, (
                f"direct collective `{name}(...)` outside the "
                f"comm-owned modules")
