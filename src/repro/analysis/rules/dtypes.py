"""Rule ``no-silent-dtype-upcast``: no f64 literals on wire paths.

Every byte claim in this repo is pinned to a model; a ``float64``
literal on a wire-path module doubles a payload (or an accumulator
feeding one) without any model noticing — jax silently downcasts
under default x64-off, so the bug additionally hides until someone
enables x64.  Host-side diagnostics that genuinely want f64 carry a
suppression comment (see `comm/faults.py`)."""
from __future__ import annotations

import ast

from repro.analysis.lint import dotted, in_dirs, module_aliases, rule

_SCOPE = in_dirs("src/repro/core/", "src/repro/comm/",
                 "src/repro/serving/", "src/repro/training/")


@rule("no-silent-dtype-upcast",
      summary="no float64 dtype literals in wire-path modules",
      rationale="an f64 literal doubles a payload the byte models "
                "never account for, and x64-off jax masks it until "
                "deployment",
      fix_hint="stay in float32 (the wire precision), or add a "
               "`# repro-lint: disable=no-silent-dtype-upcast` for a "
               "host-side diagnostic",
      applies=_SCOPE)
def check(ctx):
    """Flag ``np.float64`` / ``jnp.float64`` attribute uses and bare
    ``\"float64\"`` string literals (astype/dtype= forms)."""
    num_names = module_aliases(ctx.tree, "numpy") \
        | module_aliases(ctx.tree, "jax.numpy")
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute) and node.attr == "float64" \
                and dotted(node.value) in num_names:
            yield node.lineno, (f"f64 literal `{dotted(node)}` on a "
                                f"wire-path module")
        elif isinstance(node, ast.Constant) and node.value == "float64":
            yield node.lineno, ('f64 dtype string "float64" on a '
                                'wire-path module')
