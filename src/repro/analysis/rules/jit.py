"""Rule ``no-host-callables-in-jit``: traced functions stay pure.

``time.time()`` or ``np.random.*`` inside a jitted function runs ONCE
at trace time and bakes its value into the executable — timings that
measure compilation, "random" draws identical every step.  jax PRNG
keys and host-side timing around the jit boundary are the supported
forms."""
from __future__ import annotations

import ast

from repro.analysis.lint import dotted, in_dirs, module_aliases, rule

_TIME_FNS = ("time", "perf_counter", "perf_counter_ns", "monotonic",
             "time_ns", "sleep")
_JIT_NAMES = ("jax.jit", "jit", "jax.pmap", "pmap")


def _is_jit_decorator(dec) -> bool:
    name = dotted(dec)
    if name in _JIT_NAMES:
        return True
    if isinstance(dec, ast.Call):
        fname = dotted(dec.func)
        if fname in _JIT_NAMES:
            return True
        # functools.partial(jax.jit, static_argnames=...)
        if fname in ("functools.partial", "partial") and dec.args \
                and dotted(dec.args[0]) in _JIT_NAMES:
            return True
    return False


@rule("no-host-callables-in-jit",
      summary="no time.* / np.random / random calls inside jitted "
              "functions",
      rationale="host callables run once at trace time: the 'timing' "
                "measures compilation and the 'randomness' is a "
                "constant replayed every step",
      fix_hint="thread a jax PRNG key for randomness; time around the "
               "jit boundary (after block_until_ready) for timing",
      applies=in_dirs("src/"))
def check(ctx):
    """Walk functions decorated with jax.jit/pmap (directly, called,
    or via functools.partial) and flag host-library calls inside."""
    time_names = module_aliases(ctx.tree, "time")
    np_names = module_aliases(ctx.tree, "numpy") \
        | module_aliases(ctx.tree, "numpy.random")
    random_names = module_aliases(ctx.tree, "random")
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not any(_is_jit_decorator(d) for d in node.decorator_list):
            continue
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            name = dotted(call.func)
            if name is None or "." not in name:
                continue
            head, _, fn = name.rpartition(".")
            if head in time_names and fn in _TIME_FNS:
                yield call.lineno, (
                    f"host call `{name}()` inside jitted "
                    f"`{node.name}` — runs once at trace time")
            elif (head in np_names and fn.startswith("random")) \
                    or any(head == f"{n}.random" or head.startswith(
                        f"{n}.random.") for n in np_names) \
                    or head in random_names:
                yield call.lineno, (
                    f"host RNG `{name}(...)` inside jitted "
                    f"`{node.name}` — the draw is a trace-time "
                    f"constant")
