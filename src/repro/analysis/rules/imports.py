"""Rule ``no-raw-shard-map-import``: the mesh shim is the one door.

`repro.launch.mesh` wraps ``shard_map`` (and mesh construction) behind
the jax-0.4.x compatibility shims — AxisType, ``check_vma`` vs
``check_rep`` kwarg drift, tuple axis handling.  A direct
``jax.experimental.shard_map`` import bypasses the shim and breaks on
exactly one side of the jax version fence."""
from __future__ import annotations

import ast

from repro.analysis.lint import dotted, not_in, rule

_MESH = "src/repro/launch/mesh.py"


@rule("no-raw-shard-map-import",
      summary="shard_map is imported only via repro.launch.mesh",
      rationale="launch/mesh.py carries the jax-0.4.x compat shims "
                "(check_vma/check_rep kwarg drift, AxisType); a raw "
                "import breaks on one side of the version fence",
      fix_hint="from repro.launch.mesh import shard_map",
      applies=not_in(_MESH))
def check(ctx):
    """Flag imports of (or attribute chains into)
    ``jax.experimental.shard_map`` anywhere but the shim module."""
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom):
            if node.module == "jax.experimental.shard_map":
                yield node.lineno, ("raw jax.experimental.shard_map "
                                    "import bypasses the launch/mesh "
                                    "compat shim")
            elif node.module == "jax.experimental" and any(
                    a.name == "shard_map" for a in node.names):
                yield node.lineno, ("raw jax.experimental shard_map "
                                    "import bypasses the launch/mesh "
                                    "compat shim")
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "jax.experimental.shard_map":
                    yield node.lineno, ("raw jax.experimental."
                                        "shard_map import bypasses "
                                        "the launch/mesh compat shim")
        elif isinstance(node, ast.Attribute):
            if dotted(node) == "jax.experimental.shard_map.shard_map":
                yield node.lineno, ("raw jax.experimental.shard_map "
                                    "use bypasses the launch/mesh "
                                    "compat shim")
