"""`repro.analysis` — static analysis of the repo's own invariants.

Two layers, one CLI (``python -m repro.analysis [--json out.json]``,
wired into the CI lint job):

* **AST lint** (`repro.analysis.lint` + `repro.analysis.rules`): the
  semantic invariants that keep the byte-accounting trustworthy — no
  unfused quantize outside ``core/boundary.py``, no stray ``REPRO_*``
  env read, registry enrollment on every ``register_wire`` — as
  pluggable visitor rules with ids, fix hints and suppression
  comments, replacing the scattered ``inspect.getsource`` scans.
* **HLO collective audit** (`repro.analysis.collectives`): compiles
  every registered DP wire on the standard 4-device ring and pins its
  full collective inventory (kind, dtype, bytes, device groups,
  count) against the ``expected_collectives`` manifest declared next
  to each `WireSpec` — so a GSPMD-inserted extra collective or an f32
  all-reduce smuggled onto a compressed path fails with a diff
  instead of shipping.

Rule catalog, manifest format and how-to-add-a-rule:
``docs/ANALYSIS.md``.  The lint layer is pure stdlib; jax loads only
for the audit layer.
"""
from repro.analysis.lint import (Finding, get_rule, iter_rules,
                                 lint_text, run_lint, run_rule)

__all__ = ["Finding", "get_rule", "iter_rules", "lint_text",
           "run_lint", "run_rule"]
