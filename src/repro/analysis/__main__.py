"""CLI: ``python -m repro.analysis [--json out.json]``.

Runs the AST lint sweep and the HLO collective audit; exits nonzero on
ANY finding (CI gates on this).  The audit compiles every registered
DP wire on a 4-device host ring, so the device count is forced into
``XLA_FLAGS`` here, before jax initializes — which is also why this
entry point must stay the FIRST importer of anything jax-flavored.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _ensure_host_devices(n: int) -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}").strip()


def main(argv=None) -> int:
    """Run both layers; return 0 only when the repo is clean."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST lint rules + HLO collective audit "
                    "(docs/ANALYSIS.md)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full machine-readable report here")
    ap.add_argument("--rule", metavar="ID",
                    help="run ONE lint rule instead of the full set")
    ap.add_argument("--skip-collectives", action="store_true",
                    help="lint layer only (no jax, no wire compiles)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    from repro.analysis.lint import get_rule, iter_rules, run_lint

    rules = [get_rule(args.rule)] if args.rule else iter_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.id:>28s}  [{r.severity}]  {r.summary}")
        return 0

    findings = run_lint(rules=rules)
    for f in findings:
        print(f"LINT {f.format()}")
        if f.fix_hint:
            print(f"     fix: {f.fix_hint}")

    audits = []
    if not args.skip_collectives:
        from repro.analysis.collectives import (AUDIT_N, audit_dp_plane,
                                                format_audits)
        _ensure_host_devices(AUDIT_N)
        audits = audit_dp_plane()
        print(format_audits(audits))

    report = {
        "lint": {
            "rules": [{"id": r.id, "severity": r.severity,
                       "summary": r.summary, "rationale": r.rationale,
                       "fix_hint": r.fix_hint} for r in rules],
            "findings": [f.to_dict() for f in findings],
        },
        "collectives": [a.to_dict() for a in audits],
        "ok": not findings and all(a.ok for a in audits),
    }
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=1)

    bad_audits = sum(not a.ok for a in audits)
    print(f"repro.analysis: {len(rules)} rule(s), "
          f"{len(findings)} lint finding(s); "
          f"{len(audits)} wire audit(s), {bad_audits} failed")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
