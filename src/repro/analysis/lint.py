"""AST lint engine: the repo's semantic invariants as pluggable rules.

Ruff guards syntax and style; this engine guards the invariants that
make the byte-accounting claims trustworthy — "no unfused quantize
outside ``core/boundary.py``", "no stray ``REPRO_*`` env read", "every
``register_wire`` call ships its byte model and simulator mirror", ...
Each invariant used to live as a scattered ``inspect.getsource`` regex
test or a ``check_docs.py`` scan; here it is ONE :class:`Rule` with an
id, severity, rationale and fix hint, enforced uniformly over the whole
tree by ``python -m repro.analysis`` (CI lint job) and invocable
one-line from tests (`run_rule`).

Rules live in `repro.analysis.rules` (one module per concern) and
self-register through the :func:`rule` decorator::

    @rule("my-rule-id",
          summary="what it guards",
          rationale="why it exists",
          fix_hint="what to do instead",
          applies=in_dirs("src/repro/"))
    def _check(ctx: FileContext):
        for node in ast.walk(ctx.tree):
            ...
            yield node.lineno, "message"

Suppression
-----------
A finding is suppressed by a comment on the flagged line (or on a pure
comment line directly above it)::

    x = np.float64(loss)   # repro-lint: disable=no-silent-dtype-upcast

and a whole file opts out of one rule with::

    # repro-lint: disable-file=no-silent-dtype-upcast

``disable=all`` suppresses every rule for that line.  Suppressions are
deliberate and greppable — the lint report counts them.

This module is pure stdlib (``ast`` + ``re``): the lint layer runs
without jax so the CI lint job can gate it before any install-heavy
step.  The sibling HLO layer lives in `repro.analysis.collectives`.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Iterable, Iterator, Optional

#: directories (repo-relative) the default lint sweep walks.
SCAN_ROOTS = ("src", "tools", "benchmarks", "examples", "tests")

_DISABLE_RE = re.compile(r"#\s*repro-lint:\s*disable=([\w,\-]+)")
_DISABLE_FILE_RE = re.compile(r"#\s*repro-lint:\s*disable-file=([\w,\-]+)")


@dataclass(frozen=True)
class Finding:
    """One lint violation: rule id, location, message, and the rule's
    fix hint (carried so ``--json`` reports are self-describing)."""
    rule: str
    severity: str
    path: str                 # repo-relative posix path
    line: int
    message: str
    fix_hint: str = ""

    def format(self) -> str:
        """``path:line: [rule] message`` — the CLI print form."""
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_dict(self) -> dict:
        """JSON-report form."""
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": self.line,
                "message": self.message, "fix_hint": self.fix_hint}


@dataclass
class FileContext:
    """One parsed file as the rules see it: repo-relative posix path,
    raw text, parsed ``ast`` tree, and the physical lines (for
    suppression comments)."""
    relpath: str
    text: str
    tree: ast.Module
    lines: list = field(default_factory=list)

    @classmethod
    def parse(cls, text: str, relpath: str) -> "FileContext":
        """Parse ``text`` as the file at ``relpath`` (virtual paths are
        fine — the fixture tests lint in-memory snippets)."""
        return cls(relpath=relpath, text=text,
                   tree=ast.parse(text), lines=text.splitlines())


@dataclass(frozen=True)
class Rule:
    """One registered lint rule: identity, docs, scope and checker.

    ``check(ctx)`` yields ``(lineno, message)`` pairs; the engine turns
    them into :class:`Finding`\\ s and applies suppression comments."""
    id: str
    severity: str
    summary: str              # what it guards
    rationale: str            # why it exists
    fix_hint: str             # what to write instead
    check: Callable[[FileContext], Iterable]
    applies: Callable[[str], bool]


_RULES: dict[str, Rule] = {}


def rule(rule_id: str, *, summary: str, rationale: str, fix_hint: str,
         severity: str = "error",
         applies: Optional[Callable[[str], bool]] = None):
    """Decorator registering a checker function as a :class:`Rule`.

    ``applies`` filters repo-relative posix paths (default: every
    scanned file).  Rule ids are unique — re-registration raises."""
    def deco(fn):
        if rule_id in _RULES:
            raise ValueError(f"lint rule {rule_id!r} already registered")
        _RULES[rule_id] = Rule(
            id=rule_id, severity=severity, summary=summary,
            rationale=rationale, fix_hint=fix_hint, check=fn,
            applies=applies or (lambda relpath: True))
        return fn
    return deco


def iter_rules() -> list[Rule]:
    """All registered rules, registration order (imports
    `repro.analysis.rules` so the built-ins are present)."""
    from repro.analysis import rules as _  # noqa: F401  (self-register)
    return list(_RULES.values())


def get_rule(rule_id: str) -> Rule:
    """Look one rule up by id; unknown ids raise with the known list."""
    rules = {r.id: r for r in iter_rules()}
    if rule_id not in rules:
        raise ValueError(f"unknown lint rule {rule_id!r}; registered: "
                         f"{', '.join(sorted(rules))}")
    return rules[rule_id]


def in_dirs(*prefixes: str, exclude: tuple = ()):
    """Scope helper: path starts with any prefix and is not excluded
    (both repo-relative posix)."""
    def applies(relpath: str) -> bool:
        return (relpath.startswith(prefixes)
                and relpath not in exclude)
    return applies


def not_in(*excluded: str):
    """Scope helper: every path except the named ones."""
    def applies(relpath: str) -> bool:
        return relpath not in excluded
    return applies


# ---------------------------------------------------------------------------
# shared AST helpers (import-alias tracking) — rules compose these so
# aliased imports (`from os import environ as e`) cannot dodge a rule
# ---------------------------------------------------------------------------

def module_aliases(tree: ast.Module, module: str) -> set:
    """Every name the file binds to ``module``: ``import m``,
    ``import m as x``, and ``from pkg import mod as x`` for
    ``pkg.mod == module``.  The full dotted name itself is always
    included (``import repro.core.quantization`` is used as the full
    attribute chain)."""
    names = {module}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == module:
                    names.add(a.asname or a.name)
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                if f"{node.module}.{a.name}" == module:
                    names.add(a.asname or a.name)
    return names


def imported_names(tree: ast.Module, module: str) -> dict:
    """``from module import name [as alias]`` bindings:
    ``{local_alias: original_name}``."""
    out = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == module:
            for a in node.names:
                out[a.asname or a.name] = a.name
    return out


def dotted(node) -> Optional[str]:
    """A ``Name``/``Attribute`` chain as a dotted string
    (``jax.lax.psum``), or None for anything dynamic."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def const_str(node) -> Optional[str]:
    """The value of a string constant (or the literal head of an
    f-string), else None — enough to catch ``\"REPRO_\" + name``-style
    literal prefixes without executing anything."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.JoinedStr) and node.values:
        head = node.values[0]
        if isinstance(head, ast.Constant) and isinstance(head.value, str):
            return head.value
    return None


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def _file_disabled(ctx: FileContext) -> set:
    out = set()
    for m in _DISABLE_FILE_RE.finditer(ctx.text):
        out.update(m.group(1).split(","))
    return out


def _line_disabled(ctx: FileContext, lineno: int) -> set:
    """Suppression ids active for ``lineno``: trailing comment on the
    line itself, or a pure-comment line directly above."""
    out = set()
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(ctx.lines):
            text = ctx.lines[ln - 1]
            if ln != lineno and not text.lstrip().startswith("#"):
                continue
            m = _DISABLE_RE.search(text)
            if m:
                out.update(m.group(1).split(","))
    return out


def check_file(ctx: FileContext, rules: Optional[list] = None) -> list:
    """Run ``rules`` (default: all registered) over one parsed file,
    applying suppression comments.  Returns :class:`Finding`\\ s."""
    findings = []
    file_off = _file_disabled(ctx)
    for r in (rules if rules is not None else iter_rules()):
        if not r.applies(ctx.relpath):
            continue
        if r.id in file_off or "all" in file_off:
            continue
        for lineno, message in r.check(ctx):
            off = _line_disabled(ctx, lineno)
            if r.id in off or "all" in off:
                continue
            findings.append(Finding(
                rule=r.id, severity=r.severity, path=ctx.relpath,
                line=lineno, message=message, fix_hint=r.fix_hint))
    return findings


def lint_text(text: str, relpath: str,
              rules: Optional[list] = None) -> list:
    """Lint an in-memory snippet as if it lived at ``relpath`` — the
    seeded-violation fixture entry point."""
    return check_file(FileContext.parse(text, relpath), rules)


def repo_root() -> Path:
    """The repository root, resolved from this file's location
    (``src/repro/analysis/lint.py`` -> three parents up)."""
    return Path(__file__).resolve().parents[3]


def iter_python_files(root: Optional[Path] = None) -> Iterator[Path]:
    """Every ``*.py`` under the scan roots, sorted, caches skipped."""
    root = root or repo_root()
    for top in SCAN_ROOTS:
        d = root / top
        if not d.is_dir():
            continue
        for py in sorted(d.rglob("*.py")):
            if "__pycache__" in py.parts:
                continue
            yield py


def run_lint(root: Optional[Path] = None,
             rules: Optional[list] = None) -> list:
    """Lint the whole repo (or one rooted at ``root``).  Unparseable
    files surface as ``parse-error`` findings instead of crashing the
    sweep."""
    root = root or repo_root()
    rules = rules if rules is not None else iter_rules()
    findings = []
    for py in iter_python_files(root):
        rel = py.relative_to(root).as_posix()
        try:
            ctx = FileContext.parse(py.read_text(), rel)
        except SyntaxError as e:
            findings.append(Finding(
                rule="parse-error", severity="error", path=rel,
                line=e.lineno or 0, message=f"file does not parse: {e.msg}"))
            continue
        findings.extend(check_file(ctx, rules))
    return findings


def run_rule(rule_id: str, root: Optional[Path] = None) -> list:
    """Run ONE rule over its scope — the one-line test entry point that
    replaced the scattered ``inspect.getsource`` scans::

        assert run_rule("no-unfused-quantize") == []
    """
    return run_lint(root, rules=[get_rule(rule_id)])
