"""HLO collective auditor: pin every wire's communication graph.

The byte regression (`tests/test_hlo_cost.py`) pins each DP wire's
TOTAL collective bytes; that total cannot see a *swap* — GSPMD
replacing a cheap collective with a hidden expensive one plus an
elision (the PR-4 bucket-doubling bug class), or an f32 all-reduce
smuggled onto a compressed path.  This module pins the full
*inventory* instead: every collective op in the optimized HLO of every
registered DP wire — kind, operand dtype, per-op bytes, device-group
span, count (trip-count aware) — checked against the
``expected_collectives`` manifest each wire declares next to its
`WireSpec` registration in `repro.comm.wires`.

A manifest is a function ``(shape, bits, n) -> [(kind, dtype,
bytes_per_op, count), ...]`` — e.g. the compressed ring at
``(128, 256)``, b=2, n=4 declares one f32 scale all-reduce (512 B),
three u8 code-segment permute hops (2048 B each) and three u8
packed-sum hops (4096 B each).  The audit fails loudly, with a diff,
on: a collective missing from / extra to the manifest, a count or
byte-size drift, a reduction whose device group does not span the
mesh, a manifest whose total disagrees with the wire's ``wire_bytes``
model, or a registered collective wire with no manifest at all.  An
unexpected f32/f64 all-reduce on a ``bits < 16`` path gets a named
callout — that is exactly the compressed-path bug class.

Compilation reuses `repro.launch.hlo_cost`'s machinery: the same
``jit().lower().compile().as_text()`` entry `measure_collective_bytes`
uses, the same HLO parser, and the shared `COLLECTIVE_KINDS` constant
— one collective-kind list for the byte regression and this auditor.
A jaxpr-level pre-pass records the collective primitives the *traced*
program asked for, so a report shows both what was requested (jaxpr)
and what GSPMD actually scheduled (HLO).

jax and `repro.comm` are imported lazily: ``python -m repro.analysis``
must set the host device count before JAX initializes, and the lint
layer must stay importable without jax entirely.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.launch.hlo_cost import (COLLECTIVE_KINDS, _BODY_RE,
                                   _BRANCHES_RE, _CALLS_RE, _OPERAND,
                                   _TO_RE, _TRIP_RE, _type_bytes,
                                   parse_hlo)

# the standard audit mesh: the 4-device ring every wire regression
# compiles on, one (rows, group_d) gradient bucket, the three paper
# widths.
AUDIT_N = 4
AUDIT_SHAPE = (128, 256)
AUDIT_BITS = (2, 4, 8)

_DTYPE_RE = re.compile(r"(\w+)\[")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(\{[^=]*?\})\}")

#: jaxpr collective primitives counted by the pre-pass.
JAXPR_COLLECTIVES = ("psum", "pmax", "pmin", "pmean", "ppermute",
                     "all_gather", "psum_scatter", "all_to_all",
                     "reduce_scatter")


@dataclass(frozen=True)
class CollectiveOp:
    """One collective instruction in the optimized HLO: kind, operand
    dtype, bytes per execution, device-group span (devices per replica
    group, or source->target pairs for a permute), and how many times
    it runs (enclosing ``while`` trip counts multiplied through)."""
    kind: str
    dtype: str
    nbytes: int
    groups: int
    count: int

    def format(self) -> str:
        """``kind dtype bytes x count (groups=g)`` — diff print form."""
        return (f"{self.kind} {self.dtype} {self.nbytes} B x"
                f"{self.count} (groups={self.groups})")

    def to_dict(self) -> dict:
        """JSON-report form."""
        return {"kind": self.kind, "dtype": self.dtype,
                "bytes": self.nbytes, "groups": self.groups,
                "count": self.count}


def _group_span(line: str, kind: str) -> int:
    """Devices per replica group (reductions) or number of
    source->target pairs (permutes); 0 if the attribute is absent."""
    if kind == "collective-permute":
        m = _PAIRS_RE.search(line)
        return m.group(1).count("{") if m else 0
    m = _GROUPS_RE.search(line)
    if not m:
        return 0
    first = m.group(1).split("}")[0].lstrip("{")
    return len([d for d in first.split(",") if d.strip() != ""])


def collective_inventory(hlo_text: str) -> list:
    """Every collective op in the ENTRY program of ``hlo_text``,
    aggregated to :class:`CollectiveOp` rows (same-shaped ops merge
    into one row with a summed count).  The walk recurses through
    fusions / calls / whiles exactly like `hlo_cost` does, so scanned
    collectives count once per trip."""
    comps = parse_hlo(hlo_text)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")
    raw: dict[tuple, int] = {}

    def walk(comp, mult):
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    trip = int(tm.group(1))
                m = _BODY_RE.search(ins.line)
                if m and m.group(1) in comps:
                    walk(comps[m.group(1)], mult * trip)
                continue
            if op == "conditional":
                # every branch walks (an inventory has no "max branch"
                # — a collective in ANY branch is on the wire graph)
                m = _BRANCHES_RE.search(ins.line)
                if m:
                    for bn in _OPERAND.findall(m.group(1)):
                        if bn in comps:
                            walk(comps[bn], mult)
                continue
            if op in ("call", "async-start", "fusion"):
                m = _TO_RE.search(ins.line) or _CALLS_RE.search(ins.line)
                if m and m.group(1) in comps:
                    walk(comps[m.group(1)], mult)
                continue
            for kind in COLLECTIVE_KINDS:
                if op == kind or op == kind + "-start":
                    dm = _DTYPE_RE.search(ins.result_type)
                    key = (kind, dm.group(1) if dm else "?",
                           int(_type_bytes(ins.result_type)),
                           _group_span(ins.line, kind))
                    raw[key] = raw.get(key, 0) + mult
                    break

    walk(entry, 1)
    return [CollectiveOp(kind=k, dtype=d, nbytes=b, groups=g, count=c)
            for (k, d, b, g), c in sorted(raw.items())]


def jaxpr_collective_counts(fn, *arg_structs) -> dict:
    """Collective primitive counts in the *traced* program (recursing
    into sub-jaxprs) — what the wire asked for, before GSPMD."""
    import jax
    counts: dict[str, int] = {}

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name in JAXPR_COLLECTIVES:
                counts[name] = counts.get(name, 0) + 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):          # ClosedJaxpr
                    walk(v.jaxpr)
                elif hasattr(v, "eqns"):         # raw Jaxpr
                    walk(v)

    walk(jax.make_jaxpr(fn)(*arg_structs).jaxpr)
    return counts


@dataclass
class WireAudit:
    """The audit verdict for one (wire, bits): measured inventory,
    expected manifest rows, jaxpr request counts, and every problem
    found (empty = the wire's communication graph is exactly as
    declared)."""
    wire: str
    bits: int
    n: int
    shape: tuple
    inventory: list = field(default_factory=list)
    expected: list = field(default_factory=list)
    jaxpr: dict = field(default_factory=dict)
    problems: list = field(default_factory=list)

    @property
    def ok(self) -> bool:
        """True when the inventory matches the manifest exactly."""
        return not self.problems

    def to_dict(self) -> dict:
        """JSON-report form."""
        return {"wire": self.wire, "bits": self.bits, "n": self.n,
                "shape": list(self.shape),
                "inventory": [c.to_dict() for c in self.inventory],
                "expected": [c.to_dict() for c in self.expected],
                "jaxpr": self.jaxpr, "problems": self.problems,
                "ok": self.ok}


def _normalize_manifest(entries, n: int) -> list:
    """Manifest tuples ``(kind, dtype, bytes, count)`` ->
    :class:`CollectiveOp` rows; the expected group span on the 1-D
    audit ring is always the full mesh (n devices / n permute pairs)."""
    return [CollectiveOp(kind=k, dtype=d, nbytes=int(b), groups=n,
                         count=int(c)) for (k, d, b, c) in entries]


def _diff(audit: WireAudit) -> None:
    """Compare measured inventory to the manifest and append problem
    lines: missing / unexpected / count-drift rows, the compressed-
    path f32-all-reduce callout, and group spans that do not cover the
    mesh."""
    measured = {(c.kind, c.dtype, c.nbytes, c.groups): c.count
                for c in audit.inventory}
    expected = {(c.kind, c.dtype, c.nbytes, c.groups): c.count
                for c in audit.expected}
    for key in sorted(set(measured) | set(expected)):
        got, want = measured.get(key, 0), expected.get(key, 0)
        if got == want:
            continue
        op = CollectiveOp(*key, count=abs(got - want))
        if want == 0:
            msg = (f"unexpected collective not in the manifest: "
                   f"{op.format()} — GSPMD-inserted or smuggled op")
            if op.kind == "all-reduce" and op.dtype in ("f32", "f64") \
                    and audit.bits < 16:
                msg += (f"; a full-precision all-reduce on a "
                        f"{audit.bits}-bit compressed path is the "
                        f"PR-4 bug class")
            audit.problems.append(msg)
        elif got == 0:
            audit.problems.append(
                f"missing collective declared by the manifest: "
                f"{op.format()}")
        else:
            audit.problems.append(
                f"count drift for {op.kind} {op.dtype} {op.nbytes} B "
                f"(groups={op.groups}): measured x{got}, manifest "
                f"x{want}")
    for c in audit.inventory:
        if c.groups and c.groups != audit.n:
            audit.problems.append(
                f"{c.format()} does not span the {audit.n}-device "
                f"mesh — a partial-group collective on the DP ring")


def audit_wire(spec, bits: int, *, n: int = AUDIT_N,
               shape: tuple = AUDIT_SHAPE) -> WireAudit:
    """Compile one registered DP wire on the n-device ring (reference
    backend, deterministic rounding — the same lowering the byte
    regression measures) and audit its collective inventory against
    the wire's ``expected_collectives`` manifest and ``wire_bytes``
    model."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh_auto, shard_map

    audit = WireAudit(wire=spec.name, bits=bits, n=n, shape=shape)
    mesh = make_mesh_auto((n,), ("d",))
    pspec = P("d")

    def wire_fn(v, err, key):
        out, new_err = spec.collective(v[0], err[0], "d", bits, key,
                                       stochastic=False,
                                       backend="reference")
        return out[None], new_err[None]

    fn = shard_map(wire_fn, mesh, (pspec, pspec, P()), (pspec, pspec))
    rows, d = shape
    v = jax.ShapeDtypeStruct((n, rows, d), jnp.float32)
    err = jax.ShapeDtypeStruct((n, rows, d), jnp.float32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    text = jax.jit(fn).lower(v, err, key).compile().as_text()
    audit.inventory = collective_inventory(text)
    audit.jaxpr = jaxpr_collective_counts(fn, v, err, key)

    if spec.expected_collectives is None:
        audit.problems.append(
            f"wire {spec.name!r} has no expected_collectives manifest "
            f"— declare one next to its register_wire call")
        return audit
    manifest = spec.expected_collectives(shape, bits, n)
    audit.expected = _normalize_manifest(manifest, n)
    _diff(audit)

    model = spec.wire_bytes(shape, bits, n)
    declared = sum(c.nbytes * c.count for c in audit.expected)
    if declared != model:
        audit.problems.append(
            f"manifest total {declared} B != wire_bytes model "
            f"{model} B — the manifest and byte model drifted apart")
    return audit


def audit_dp_plane(bits=AUDIT_BITS, *, n: int = AUDIT_N,
                   shape: tuple = AUDIT_SHAPE) -> list:
    """Audit EVERY user-selectable wire registered on the dp-grad
    plane at every width in ``bits`` — registry-derived, so a new wire
    enrolls automatically and cannot land unaudited."""
    from repro.comm import wires as W
    return [audit_wire(W.get_wire(name), b, n=n, shape=shape)
            for name in W.wire_names("dp-grad") for b in bits]


def format_audits(audits: list) -> str:
    """Human-readable audit report: one line per clean (wire, bits),
    the full diff for any failure."""
    lines = []
    for a in audits:
        head = (f"{a.wire:>14s} b={a.bits}  "
                f"{sum(c.nbytes * c.count for c in a.inventory):>8d} B "
                f"in {sum(c.count for c in a.inventory)} collective(s)")
        lines.append(("OK   " if a.ok else "FAIL ") + head)
        if not a.ok:
            for c in a.inventory:
                lines.append(f"        measured: {c.format()}")
            for c in a.expected:
                lines.append(f"        manifest: {c.format()}")
            for p in a.problems:
                lines.append(f"     !! {p}")
    return "\n".join(lines)
