"""Mamba2 (SSD — state-space duality) block, pure JAX.

Chunked matmul formulation for train/prefill (arXiv:2405.21060 §6):
within-chunk terms are attention-like matmuls (MXU-friendly), the
inter-chunk recurrence is a lax.scan over chunk states.  Decode uses the
O(1) recurrent state update.

Shapes (g = ssm_groups = 1 throughout):
  x_in   (B, L, d_model)
  z, xh  (B, L, d_inner),  d_inner = expand * d_model
  Bc, Cc (B, L, n)         n = ssm_state
  dt     (B, L, h)         h = d_inner // headdim
  state  (B, h, p, n)      p = headdim
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm


def init_mamba2(key, cfg):
    d, di = cfg.d_model, cfg.d_inner
    n, h, w = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv_width
    g = cfg.ssm_groups
    conv_dim = di + 2 * g * n
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "in_proj": jax.random.normal(
            ks[0], (d, 2 * di + 2 * g * n + h)) * s,
        "conv_w": jax.random.normal(ks[1], (conv_dim, w)) * (1.0 / w),
        "conv_b": jnp.zeros((conv_dim,)),
        "dt_bias": jnp.log(jnp.exp(
            jnp.linspace(1e-3, 1e-1, h)) - 1.0),          # softplus^-1
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)),
        "D": jnp.ones((h,)),
        "norm": {"scale": jnp.zeros((di,))},
        "out_proj": jax.random.normal(ks[2], (di, d)) * (1.0 / math.sqrt(di)),
    }


def _split_proj(cfg, proj):
    di, n, h, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_groups
    z, xBC, dt = jnp.split(proj, [di, 2 * di + 2 * g * n], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv.  xBC: (B, L, C); w: (C, width)."""
    width = w.shape[-1]
    pads = jnp.pad(xBC, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(width):
        out = out + pads[:, i:i + xBC.shape[1], :].astype(jnp.float32) \
            * w[:, i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xBC.dtype)


def _segsum(x):
    """x: (..., q) -> (..., q, q) with out[i, j] = sum_{j < m <= i} x[m]."""
    xc = jnp.cumsum(x, axis=-1)
    diff = xc[..., :, None] - xc[..., None, :]
    q = x.shape[-1]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, dt, A, Bc, Cc, chunk: int, initial_state=None):
    """Chunked SSD scan.

    xh (B,L,h,p) dt (B,L,h) A (h,) Bc,Cc (B,L,n).
    Returns y (B,L,h,p) and final state (B,h,p,n).
    """
    b, l, h, p = xh.shape
    n = Bc.shape[-1]
    pad = (-l) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))       # dt=0 -> no-op
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    lp = l + pad
    c = lp // chunk
    f32 = jnp.float32
    xs = (xh.astype(f32) * dt.astype(f32)[..., None]).reshape(
        b, c, chunk, h, p)                                  # input-scaled
    xr = xh.astype(f32).reshape(b, c, chunk, h, p)
    dA = (dt.astype(f32) * A.astype(f32)).reshape(b, c, chunk, h)
    Bc = Bc.astype(f32).reshape(b, c, chunk, n)
    Cc = Cc.astype(f32).reshape(b, c, chunk, n)

    dA_cs = jnp.cumsum(dA, axis=2)                          # (b,c,q,h)
    # --- intra-chunk (diagonal blocks) ---
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))       # (b,c,h,q,q)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)              # (b,c,q,q)
    M = Lmat * CB[:, :, None, :, :]
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", M, xs)
    # --- chunk states ---
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)     # (b,c,q,h)
    states = jnp.einsum("bcin,bcih,bcihp->bchpn", Bc, decay_states, xs)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])               # (b,c,h)

    def step(carry, inp):
        st_c, dec_c = inp
        new = carry * dec_c[:, :, None, None] + st_c
        return new, carry                                    # emit incoming

    if initial_state is None:
        init = jnp.zeros((b, h, p, n), f32)
    else:
        init = initial_state.astype(f32)
    final_state, state_in = jax.lax.scan(
        step, init, (states.transpose(1, 0, 2, 3, 4),
                     chunk_decay.transpose(1, 0, 2)))
    state_in = state_in.transpose(1, 0, 2, 3, 4)             # (b,c,h,p,n)
    # --- inter-chunk contribution ---
    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc, state_in,
                       jnp.exp(dA_cs))
    y = (y_diag + y_off).reshape(b, lp, h, p)[:, :l]
    return y.astype(xh.dtype), final_state


def mamba2_forward(p, x, cfg, initial_state=None):
    """Full Mamba2 mixer.  x: (B, L, d_model) -> (out, state_dict).

    state_dict carries the recurrent handoff for decode: the final SSD
    state and the raw (pre-conv) tail window feeding the causal conv.
    """
    b, l, _ = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hd = cfg.ssm_headdim
    dtype = x.dtype
    proj = x @ p["in_proj"].astype(dtype)
    z, xBC_raw, dt_raw = _split_proj(cfg, proj)
    conv_tail = xBC_raw[:, -(cfg.ssm_conv_width - 1):, :]
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xh, Bc, Cc = jnp.split(xBC, [di, di + n], axis=-1)
    xh = xh.reshape(b, l, h, hd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, final_state = ssd_chunked(xh, dt, A, Bc, Cc, cfg.ssm_chunk,
                                 initial_state)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(b, l, di).astype(dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    state = {"ssm": final_state, "conv": conv_tail}
    return y @ p["out_proj"].astype(dtype), state


def mamba2_decode_step(p, x, cfg, ssm_state, conv_state):
    """Single-token recurrent update.

    x: (B, 1, d_model); ssm_state (B,h,p,n); conv_state (B,width-1,conv_dim).
    """
    b = x.shape[0]
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hd = cfg.ssm_headdim
    dtype = x.dtype
    proj = (x[:, 0] @ p["in_proj"].astype(dtype))
    z, xBC, dt_raw = _split_proj(cfg, proj)
    # conv over the stored window
    window = jnp.concatenate(
        [conv_state, xBC[:, None, :].astype(conv_state.dtype)], axis=1)
    conv_out = jnp.sum(window.astype(jnp.float32)
                       * p["conv_w"].astype(jnp.float32).T[None], axis=1)
    xBC = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)
                      ).astype(dtype)
    new_conv_state = window[:, 1:]
    xh, Bc, Cc = jnp.split(xBC, [di, di + n], axis=-1)
    xh = xh.reshape(b, h, hd).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,h)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                       # (B,h)
    Bf, Cf = Bc.astype(jnp.float32), Cc.astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bf)
    new_state = ssm_state.astype(jnp.float32) * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cf)
    y = y + xh * p["D"].astype(jnp.float32)[:, None]
    y = y.reshape(b, di).astype(dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ p["out_proj"].astype(dtype))[:, None, :]
    return out, new_state.astype(ssm_state.dtype), new_conv_state
