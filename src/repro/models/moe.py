"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Covers both assigned MoE styles:
* Mixtral:   8 experts, top-2, no shared experts.
* DeepSeek / Moonlight: 64 fine-grained experts, top-6, +2 shared experts
  (dense FFNs always applied), leading dense layers handled by the model.

Dispatch is the production-style sort/scatter form (argsort tokens by
expert id, cumsum position-in-expert, capacity drop) so compiled FLOPs
scale with *active* experts (top_k × capacity_factor), not with E — this
is what makes the roofline numbers honest for MoE archs.  A dense
reference (`moe_dense_reference`) computes the exact no-drop answer for
the unit tests.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import init_mlp, mlp


def init_moe(key, d_model: int, n_experts: int, moe_d_ff: int,
             n_shared: int = 0, gated: bool = True):
    kr, ke, ks = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(moe_d_ff)
    p = {
        "router": jax.random.normal(kr, (d_model, n_experts)) * s_in,
        "w_gate": jax.random.normal(
            ke, (n_experts, d_model, moe_d_ff)) * s_in,
        "w_up": jax.random.normal(
            jax.random.fold_in(ke, 1), (n_experts, d_model, moe_d_ff)) * s_in,
        "w_down": jax.random.normal(
            jax.random.fold_in(ke, 2), (n_experts, moe_d_ff, d_model)) * s_out,
    }
    if n_shared:
        # n_shared same-size experts fused into one wide dense FFN
        p["shared"] = init_mlp(ks, d_model, n_shared * moe_d_ff, gated=gated)
    return p


def router_probs(p, x):
    """x: (T, d) -> router softmax probs (T, E), computed in fp32."""
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    return jax.nn.softmax(logits, axis=-1)


def make_local_ep_weights(ep_axis, ep_size: int):
    """ep_weights for UNsharded expert stacks: device g just slices its
    own experts locally (used by tests and replicated-weight setups).
    The FSDP-sharded version (weight all_to_all) lives in
    training.pipeline."""
    def ep_weights(name, leaf):
        e = leaf.shape[0]
        ne = max(e // ep_size, 1)
        g = jax.lax.axis_index(ep_axis)
        start = g * e // ep_size
        return jax.lax.dynamic_slice_in_dim(leaf, start, ne, axis=0)
    return ep_weights


def moe_ffn(p, x, *, top_k: int, capacity_factor: float, act: str = "silu",
            deterministic_capacity: int = 0, expert_map=None,
            per_sequence: bool = False, ep_axis=None, ep_size: int = 0,
            ep_weights=None):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    expert_map(name, stacked_leaf, e) -> full (d, ff)/(ff, d) weight of
    expert e.  When given, experts are processed with a lax.scan and each
    expert's weights are materialized one at a time — the pipeline runtime
    uses this to bound the transient footprint of ZeRO-3 gathers (a
    mixtral-8x22b layer is ~4.8 GB gathered at once, ~0.6 GB per expert).

    per_sequence=True dispatches each sequence independently (vmap over
    batch).  Under GSPMD pjit serving this keeps the data-dependent
    argsort/gather/scatter local to the batch shard — a single global
    dispatch over B·S tokens makes the SPMD partitioner replicate the
    (T·k, d) gathers (51 GB/device on mixtral prefill_32k).  Capacity is
    then per-sequence (drop behavior is batch-independent — also nice for
    serving determinism).
    """
    if per_sequence:
        def one(xb):
            return moe_ffn(p, xb[None], top_k=top_k,
                           capacity_factor=capacity_factor, act=act,
                           deterministic_capacity=deterministic_capacity,
                           expert_map=expert_map)
        out, aux = jax.vmap(one)(x)
        return out[:, 0], jnp.mean(aux)
    if ep_axis is not None and ep_weights is None:
        ep_weights = make_local_ep_weights(ep_axis, ep_size)
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    e = p["router"].shape[-1]
    probs = router_probs(p, xf)                           # (T, E)
    top_v, top_i = jax.lax.top_k(probs, top_k)            # (T, k)
    top_v = top_v / jnp.sum(top_v, axis=-1, keepdims=True)

    cap = deterministic_capacity or int(
        math.ceil(t * top_k / e * capacity_factor))
    if ep_axis is not None:
        # expert-parallel: E*cap must split evenly across the axis
        m = ep_size // math.gcd(e, ep_size)
        cap = -(-cap // m) * m
    flat_e = top_i.reshape(-1)                            # (T*k,)
    order = jnp.argsort(flat_e)                           # stable
    se = flat_e[order]
    tok = order // top_k
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts                  # exclusive
    pos = jnp.arange(t * top_k) - starts[se]
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)       # dropped -> sentinel

    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(xf[tok])
    buf = buf[:e * cap].reshape(e, cap, d)

    fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    dtype = x.dtype
    if ep_axis is not None:
        y = _expert_parallel_ffn(p, buf, ep_weights, fn, dtype,
                                 ep_axis, ep_size)
    elif expert_map is None:
        h = fn(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dtype))) \
            * jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dtype))
        y = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dtype))
    else:
        def one_expert(_, ei):
            be = jax.lax.dynamic_index_in_dim(buf, ei, 0, keepdims=False)
            wg = expert_map("w_gate", p["w_gate"], ei).astype(dtype)
            wu = expert_map("w_up", p["w_up"], ei).astype(dtype)
            wd = expert_map("w_down", p["w_down"], ei).astype(dtype)
            he = fn(be @ wg) * (be @ wu)
            return None, he @ wd
        # checkpoint: the backward re-gathers each expert's weights instead
        # of keeping all E gathered copies live (4.8 GB/layer on mixtral)
        _, y = jax.lax.scan(jax.checkpoint(one_expert), None,
                            jnp.arange(e, dtype=jnp.int32))
    y = jnp.concatenate([y.reshape(e * cap, d),
                         jnp.zeros((1, d), dtype)], axis=0)

    w = top_v.reshape(-1)[order].astype(dtype)
    contrib = y[slot] * w[:, None]
    out = jnp.zeros((t, d), dtype).at[tok].add(contrib).reshape(b, s, d)

    # Switch-style load-balance loss: E * sum_e f_e * P_e
    frac = jnp.mean(jax.nn.one_hot(top_i[:, 0], e, dtype=jnp.float32),
                    axis=0)
    mean_p = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * mean_p)

    if "shared" in p:
        out = out + mlp(p["shared"], x, act=act)
    return out, aux


def _expert_parallel_ffn(p, buf, ep_weights, fn, dtype, ep_axis,
                         ep_size: int):
    """Expert-parallel expert compute inside shard_map.

    Instead of ZeRO-gathering every expert's weights on every device
    (mixtral: ~4.8 GB/layer/tick), tokens travel to the experts: the
    dispatch buffer is all_to_all'd over `ep_axis` so device g computes
    only experts [g·E/D, (g+1)·E/D) (E >= D) or its 1/(D/E) token shard
    of expert g·E/D (E < D).  Device g's expert weights arrive via
    `ep_weights(name)` — a weight all_to_all costing 1/D of the zero3
    gather (see training.pipeline).  Token wire per layer: 2 × E·cap·d
    activations.  The inverse all_to_all restores the dispatch layout,
    so combine/scatter code is unchanged.

    buf: (E, cap, d) local dispatch buffer.  Requires E*cap % D == 0
    (capacity is rounded up by the caller).
    """
    e, cap, d = buf.shape
    dd = ep_size
    ne = max(e // dd, 1)                   # experts computed per device
    chunk = e * cap // dd                  # rows sent to each device

    send = buf.reshape(dd, chunk, d)
    recv = jax.lax.all_to_all(send, ep_axis, split_axis=0, concat_axis=0,
                              tiled=False)                 # (D, chunk, d)
    # rows for my expert e_loc from every source, contiguous per expert
    recv = recv.reshape(dd, ne, chunk // ne, d)
    recv = jnp.moveaxis(recv, 1, 0).reshape(ne, dd * (chunk // ne), d)

    wg_all = ep_weights("w_gate", p["w_gate"]).astype(dtype)  # (ne, d, ff)
    wu_all = ep_weights("w_up", p["w_up"]).astype(dtype)
    wd_all = ep_weights("w_down", p["w_down"]).astype(dtype)

    def one(_, e_loc):
        ix = lambda a: jax.lax.dynamic_index_in_dim(a, e_loc, 0,
                                                    keepdims=False)
        be = ix(recv)
        he = fn(be @ ix(wg_all)) * (be @ ix(wu_all))
        return None, he @ ix(wd_all)

    _, y = jax.lax.scan(jax.checkpoint(one), None,
                        jnp.arange(ne, dtype=jnp.int32))   # (ne, rows, d)
    y = y.reshape(ne, dd, chunk // ne, d)
    y = jnp.moveaxis(y, 0, 1).reshape(dd, chunk, d)
    y = jax.lax.all_to_all(y, ep_axis, split_axis=0, concat_axis=0,
                           tiled=False)
    return y.reshape(e, cap, d)


def moe_dense_reference(p, x, *, top_k: int, act: str = "silu"):
    """Exact (drop-free) reference: every expert on every token, masked."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    e = p["router"].shape[-1]
    probs = router_probs(p, xf)
    top_v, top_i = jax.lax.top_k(probs, top_k)
    top_v = top_v / jnp.sum(top_v, axis=-1, keepdims=True)
    gates = jnp.zeros_like(probs)
    gates = gates.at[jnp.arange(b * s)[:, None], top_i].set(top_v)  # (T,E)

    fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    dtype = x.dtype
    h = fn(jnp.einsum("td,edf->tef", xf, p["w_gate"].astype(dtype))) \
        * jnp.einsum("td,edf->tef", xf, p["w_up"].astype(dtype))
    y = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(dtype))
    out = jnp.einsum("ted,te->td", y, gates.astype(dtype)).reshape(b, s, d)
    if "shared" in p:
        out = out + mlp(p["shared"], x, act=act)
    return out
