"""Unified model builder: config -> init / train-forward / prefill / decode.

One code path per *family* (dense-like, ssm, hybrid, audio), all built from
the shared sublayers.  Trunks are `lax.scan`s over layer-stacked params so
full-scale HLOs stay small (critical: this container compiles on one CPU
core) and so the pipeline runtime can shard the same stacked arrays over
the `model` mesh axis.

Layer heterogeneity (gemma2 local/global windows) is *data* — a per-layer
window vector — so every scanned layer is structurally identical.
DeepSeek-style leading dense layers live outside the scan ("prefix").
Zamba2 is scanned as uniform super-blocks of (shared_attn_every mamba
layers + the shared attention block).

The trunk accepts an optional ``boundary_fn`` invoked between pipeline
stage groups — this is where AQ-SGD / DirectQ compression plugs in for the
bit-faithful simulated trainer (training/simulated.py).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

Params = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_attn_layer(cfg: ModelConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": L.init_rmsnorm(cfg.d_model),
         "attn": L.init_attention(k1, cfg.d_model, cfg.num_heads,
                                  cfg.num_kv_heads, cfg.head_dim),
         "norm2": L.init_rmsnorm(cfg.d_model)}
    return p, (k2, k3)


def _init_dense_layer(cfg: ModelConfig, key):
    p, (k2, _) = _init_attn_layer(cfg, key)
    p["ffn"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated)
    return p


def _init_moe_layer(cfg: ModelConfig, key):
    p, (k2, _) = _init_attn_layer(cfg, key)
    p["ffn"] = M.init_moe(k2, cfg.d_model, cfg.n_experts, cfg.moe_d_ff,
                          cfg.n_shared_experts, gated=cfg.mlp_gated)
    return p


def _init_mamba_layer(cfg: ModelConfig, key):
    return {"norm1": L.init_rmsnorm(cfg.d_model),
            "mamba": S.init_mamba2(key, cfg)}


def _init_enc_layer(cfg: ModelConfig, key):
    return _init_dense_layer(cfg, key)


def _init_dec_layer(cfg: ModelConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    p = _init_dense_layer(cfg, k1)
    p["norm_x"] = L.init_rmsnorm(cfg.d_model)
    p["xattn"] = L.init_attention(k2, cfg.d_model, cfg.num_heads,
                                  cfg.num_kv_heads, cfg.head_dim)
    return p


def _stack_init(init_one: Callable, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_one)(keys)


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    p: dict = {
        "embed": jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model))
        * 0.02,
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = jax.random.normal(
            ks[1], (cfg.d_model, cfg.vocab_size)) / math.sqrt(cfg.d_model)

    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        n_scan = cfg.num_layers - cfg.first_dense_layers
        if cfg.first_dense_layers:
            p["prefix"] = [
                _init_dense_layer(cfg, jax.random.fold_in(ks[2], i))
                for i in range(cfg.first_dense_layers)]
        init_one = (functools.partial(_init_moe_layer, cfg) if cfg.has_moe
                    else functools.partial(_init_dense_layer, cfg))
        p["layers"] = _stack_init(init_one, ks[3], n_scan)
    elif fam == "ssm":
        p["layers"] = _stack_init(
            functools.partial(_init_mamba_layer, cfg), ks[3], cfg.num_layers)
    elif fam == "hybrid":
        p["layers"] = _stack_init(
            functools.partial(_init_mamba_layer, cfg), ks[3], cfg.num_layers)
        sp = _init_dense_layer(cfg, ks[4])
        p["shared_block"] = sp
    elif fam == "audio":
        p["enc_layers"] = _stack_init(
            functools.partial(_init_enc_layer, cfg), ks[3],
            cfg.encoder_layers)
        p["enc_norm"] = L.init_rmsnorm(cfg.d_model)
        p["layers"] = _stack_init(
            functools.partial(_init_dec_layer, cfg), ks[4], cfg.num_layers)
    else:
        raise ValueError(fam)
    return p


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def window_vector(cfg: ModelConfig, seq_len: int, n: int,
                  offset: int = 0) -> jax.Array:
    return jnp.array([cfg.layer_window(i + offset, seq_len)
                      for i in range(n)], jnp.int32)


def _attn_ffn_layer(cfg: ModelConfig, lp, h, positions, window, *,
                    cache=None, cache_index=None, block_k=512,
                    expert_map=None, moe_per_sequence=False,
                    moe_ep=None):
    """One dense/moe decoder layer.  Returns (h, new_cache, aux)."""
    a, new_cache = L.attention(
        lp["attn"], L.rmsnorm(lp["norm1"], h, cfg.norm_eps),
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        positions=positions, window=window, attn_softcap=cfg.attn_softcap,
        kv_cache=cache, cache_index=cache_index, block_k=block_k)
    h = h + a
    hn = L.rmsnorm(lp["norm2"], h, cfg.norm_eps)
    if "router" in lp.get("ffn", {}):
        ep_axis, ep_size, ep_w = moe_ep if moe_ep else (None, 0, None)
        f, aux = M.moe_ffn(lp["ffn"], hn, top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           act=cfg.act, expert_map=expert_map,
                           per_sequence=moe_per_sequence,
                           ep_axis=ep_axis, ep_size=ep_size,
                           ep_weights=ep_w)
    else:
        f, aux = L.mlp(lp["ffn"], hn, act=cfg.act), 0.0
    return h + f, new_cache, aux


def _mamba_layer(cfg: ModelConfig, lp, h):
    out, _ = S.mamba2_forward(
        lp["mamba"], L.rmsnorm(lp["norm1"], h, cfg.norm_eps), cfg)
    return h + out


# ---------------------------------------------------------------------------
# trunk (training / prefill forward), with optional stage boundaries
# ---------------------------------------------------------------------------

def _scan_layers(step, h, stacked, xs_extra=None, remat=False):
    body = jax.checkpoint(step) if remat else step
    xs = (stacked,) if xs_extra is None else (stacked, *xs_extra)
    (h, aux), _ = jax.lax.scan(lambda c, x: (body(c, x), None), (h, 0.0), xs)
    return h, aux


def trunk_forward(params: Params, cfg: ModelConfig, h: jax.Array,
                  positions: jax.Array, *,
                  num_stages: int = 1,
                  boundary_fn: Optional[Callable] = None,
                  boundary_state: Any = None,
                  remat: bool = False,
                  block_k: int = 512):
    """Apply the layer trunk.  h: (B, S, d) post-embedding.

    ``boundary_fn(state, h, idx) -> (state, h)`` runs between stage groups
    (idx = 0 .. num_stages-2).  Returns (h, aux_loss, boundary_state).
    """
    fam = cfg.family
    seq = h.shape[1]
    aux_total = 0.0

    if fam in ("dense", "vlm", "moe", "audio", "ssm"):
        n_scan = cfg.num_layers - cfg.first_dense_layers
        offset = cfg.first_dense_layers
        for i, lp in enumerate(params.get("prefix", [])):
            h, _, aux = _attn_ffn_layer(cfg, lp, h, positions,
                                        cfg.layer_window(i, seq),
                                        block_k=block_k)
            aux_total += aux
        assert n_scan % num_stages == 0, (cfg.name, n_scan, num_stages)
        per_stage = n_scan // num_stages
        windows = window_vector(cfg, seq, n_scan, offset)

        if fam == "audio":
            xk_all, xv_all = params["_enc_out"]   # (L,B,Se,Hk,hd) each

            def step(carry, xs):
                hh, aux = carry
                lp, w, k_l, v_l = xs
                hh, _, a = _attn_ffn_layer(cfg, lp, hh, positions, w,
                                           block_k=block_k)
                xa, _ = L.attention(
                    lp["xattn"],
                    L.rmsnorm(lp["norm_x"], hh, cfg.norm_eps),
                    num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                    head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
                    positions=positions, window=L.BIG_WINDOW,
                    cross_kv=(k_l, v_l), block_k=block_k)
                return (hh + xa, aux + a)
        elif fam == "ssm":
            def step(carry, xs):
                hh, aux = carry
                lp, _ = xs
                return (_mamba_layer(cfg, lp, hh), aux)
        else:
            def step(carry, xs):
                hh, aux = carry
                lp, w = xs
                hh, _, a = _attn_ffn_layer(cfg, lp, hh, positions, w,
                                           block_k=block_k)
                return (hh, aux + a)

        for s in range(num_stages):
            sl = slice(s * per_stage, (s + 1) * per_stage)
            stacked = jax.tree.map(lambda a: a[sl], params["layers"])
            if fam == "audio":
                xs_extra = (windows[sl], xk_all[sl], xv_all[sl])
            else:
                xs_extra = (windows[sl],)
            h, aux = _scan_layers(step, h, stacked, xs_extra, remat=remat)
            aux_total += aux
            if boundary_fn is not None and s < num_stages - 1:
                boundary_state, h = boundary_fn(boundary_state, h, s)
        return h, aux_total, boundary_state

    if fam == "hybrid":
        per = cfg.shared_attn_every
        n_blocks = cfg.num_layers // per
        assert n_blocks % num_stages == 0, (cfg.name, n_blocks, num_stages)

        def block_step(carry, xs):
            hh, aux = carry
            (blk_params,) = xs
            def inner(c, lp):
                return (_mamba_layer(cfg, lp, c), None)
            hh, _ = jax.lax.scan(inner, hh, blk_params)
            hh, _, _ = _attn_ffn_layer(cfg, params["shared_block"], hh,
                                       positions, seq, block_k=block_k)
            return (hh, aux)

        blocks = jax.tree.map(
            lambda a: a.reshape(n_blocks, per, *a.shape[1:]),
            params["layers"])
        per_stage = n_blocks // num_stages
        for s in range(num_stages):
            sl = slice(s * per_stage, (s + 1) * per_stage)
            stacked = jax.tree.map(lambda a: a[sl], blocks)
            h, aux = _scan_layers(block_step, h, stacked, remat=remat)
            aux_total += aux
            if boundary_fn is not None and s < num_stages - 1:
                boundary_state, h = boundary_fn(boundary_state, h, s)
        return h, aux_total, boundary_state

    raise ValueError(fam)


def encode_audio(params: Params, cfg: ModelConfig, frames: jax.Array,
                 remat: bool = False, block_k: int = 512):
    """Whisper encoder over stubbed frame embeddings (B, S_enc, d)."""
    h = frames
    pos = jnp.broadcast_to(
        jnp.arange(h.shape[1], dtype=jnp.int32), h.shape[:2])

    def step(carry, xs):
        hh, aux = carry
        (lp,) = xs
        a, _ = L.attention(lp["attn"],
                           L.rmsnorm(lp["norm1"], hh, cfg.norm_eps),
                           num_heads=cfg.num_heads,
                           num_kv_heads=cfg.num_kv_heads,
                           head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
                           positions=pos, window=L.BIG_WINDOW, causal=False,
                           block_k=block_k)
        hh = hh + a
        hh = hh + L.mlp(lp["ffn"], L.rmsnorm(lp["norm2"], hh, cfg.norm_eps),
                        act=cfg.act)
        return (hh, aux)

    h, _ = _scan_layers(step, h, params["enc_layers"], remat=remat)
    return L.rmsnorm(params["enc_norm"], h, cfg.norm_eps)


# ---------------------------------------------------------------------------
# embedding / head / losses
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens, extra_embeds=None):
    """tokens (..., S_text) -> (..., S, d); extra_embeds (patches/frames)
    are prepended along the sequence dim (pixtral stub)."""
    h = params["embed"].astype(cfg.jax_dtype)[tokens]
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=-2)
    return h


def lm_logits(params, cfg: ModelConfig, h):
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = h @ head.astype(h.dtype)
    return L.softcap(logits.astype(jnp.float32), cfg.final_softcap)


def cross_entropy(logits, targets, mask):
    """logits (B,S,V) fp32; targets (B,S) int; mask (B,S) {0,1}."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params: Params, cfg: ModelConfig, batch: dict, *,
            num_stages: int = 1, boundary_fn=None, boundary_state=None,
            remat: bool = False, block_k: int = 512):
    """batch: tokens (B,S_t), targets (B,S_t), mask (B,S_t), optional
    patches (B,P,d) [vlm] or frames (B,S_enc,d) [audio]."""
    tokens = batch["tokens"]
    extra = batch.get("patches")
    h = embed_tokens(params, cfg, tokens, extra)
    b, s = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.family == "audio":
        params = dict(params)
        enc = encode_audio(params, cfg, batch["frames"], remat=remat,
                           block_k=block_k)
        # pre-compute per-layer cross kv lazily inside layers from enc
        params["_enc_out"] = _cross_kv_all(params, cfg, enc)
    h, aux, boundary_state = trunk_forward(
        params, cfg, h, positions, num_stages=num_stages,
        boundary_fn=boundary_fn, boundary_state=boundary_state,
        remat=remat, block_k=block_k)
    if extra is not None:                       # drop patch positions
        h = h[:, extra.shape[1]:]
    logits = lm_logits(params, cfg, h)
    ce = cross_entropy(logits, batch["targets"], batch["mask"])
    total = ce + cfg.router_aux_weight * aux
    return total, {"ce": ce, "aux": aux, "boundary_state": boundary_state}


# ---------------------------------------------------------------------------
# serving: caches, prefill, single-token decode
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch_size: int, cache_len: int,
                dtype=jnp.bfloat16) -> dict:
    """Zero caches for prefill/decode.  Shapes mirror the dry-run specs."""
    b, hk, hd = batch_size, cfg.num_kv_heads, cfg.head_dim
    caches: dict = {"pos": jnp.zeros((), jnp.int32)}
    fam = cfg.family
    if fam in ("dense", "vlm", "moe", "audio"):
        n_scan = cfg.num_layers - cfg.first_dense_layers
        caches["k"] = jnp.zeros((n_scan, b, cache_len, hk, hd), dtype)
        caches["v"] = jnp.zeros((n_scan, b, cache_len, hk, hd), dtype)
        if cfg.first_dense_layers:
            caches["pk"] = jnp.zeros(
                (cfg.first_dense_layers, b, cache_len, hk, hd), dtype)
            caches["pv"] = jnp.zeros_like(caches["pk"])
        if fam == "audio":
            caches["xk"] = jnp.zeros(
                (cfg.num_layers, b, cfg.encoder_seq, hk, hd), dtype)
            caches["xv"] = jnp.zeros_like(caches["xk"])
    if fam in ("ssm", "hybrid"):
        h, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        caches["ssm"] = jnp.zeros(
            (cfg.num_layers, b, h, p, n), jnp.float32)
        caches["conv"] = jnp.zeros(
            (cfg.num_layers, b, cfg.ssm_conv_width - 1, conv_dim), dtype)
    if fam == "hybrid":
        n_blocks = cfg.num_layers // cfg.shared_attn_every
        caches["k"] = jnp.zeros((n_blocks, b, cache_len, hk, hd), dtype)
        caches["v"] = jnp.zeros_like(caches["k"])
    return caches


def _trivial_expert_map(name, leaf, e):
    return jax.lax.dynamic_index_in_dim(leaf, e, 0, keepdims=False)


def _attn_layer_cached(cfg, lp, h, positions, window, cache_k, cache_v,
                       cache_index, block_k, xkv=None):
    """Dense/MoE layer with cache read/write; returns h, (k, v), aux."""
    # prefill (S >> 1): per-sequence dispatch keeps sort/scatter local to
    # the batch shard; sequential expert scan bounds (E, cap, ff) temps
    prefill_moe = cfg.has_moe and h.shape[1] > 1
    emap = _trivial_expert_map if prefill_moe else None
    h, new_cache, aux = _attn_ffn_layer(
        cfg, lp, h, positions, window,
        cache={"k": cache_k, "v": cache_v}, cache_index=cache_index,
        block_k=block_k, expert_map=emap, moe_per_sequence=prefill_moe)
    if xkv is not None:                       # audio cross attention
        xa, _ = L.attention(
            lp["xattn"], L.rmsnorm(lp["norm_x"], h, cfg.norm_eps),
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            positions=positions, window=L.BIG_WINDOW,
            cross_kv=xkv, block_k=block_k)
        h = h + xa
    return h, (new_cache["k"], new_cache["v"]), aux


def _staged_cached_scan(step, carry, xs, *, num_stages, boundary_fn,
                        boundary_state, get_h, set_h):
    """`lax.scan` over stacked layers, cut into ``num_stages``
    contiguous chunks with ``boundary_fn(state, h, idx)`` applied to
    the carried hidden state between chunks — the serving mirror of
    `trunk_forward`'s stage loop, for scans that also thread per-layer
    cache slices through ``xs``/``ys``.  Returns
    (carry, ys, boundary_state)."""
    if num_stages == 1 or boundary_fn is None:
        carry, ys = jax.lax.scan(step, carry, xs)
        return carry, ys, boundary_state
    n = jax.tree.leaves(xs)[0].shape[0]
    assert n % num_stages == 0, (n, num_stages)
    per = n // num_stages
    parts = []
    for si in range(num_stages):
        sl = slice(si * per, (si + 1) * per)
        carry, y = jax.lax.scan(step, carry,
                                jax.tree.map(lambda a: a[sl], xs))
        parts.append(y)
        if si < num_stages - 1:
            boundary_state, h = boundary_fn(boundary_state,
                                            get_h(carry), si)
            carry = set_h(carry, h)
    ys = jax.tree.map(lambda *p: jnp.concatenate(p, axis=0), *parts)
    return carry, ys, boundary_state


def forward_with_caches(params: Params, cfg: ModelConfig, tokens, caches,
                        *, patches=None, frames=None, block_k: int = 512,
                        logits_last_only: bool = False,
                        num_stages: int = 1, boundary_fn=None,
                        kv_codec=None):
    """Unified prefill (S > 1) / decode (S = 1) step.

    tokens: (B, S).  Returns (logits (B, S, V) fp32, new_caches).
    logits_last_only: return only the final position's logits — essential
    for full-scale prefill (B×S×V logits would be TBs).

    Serving-plane hooks (`repro.serving`):

    * ``num_stages``/``boundary_fn`` — cut the layer scan into pipeline
      stage groups and run ``boundary_fn(state, h, idx) -> (state, h)``
      on the hidden state between them (the compressed decode hop,
      `serving.delta.DeltaHopCodec`).  The hop's reference buffers ride
      IN the cache dict under ``"hop_m"`` (f32 (nb, B, 1, d)) so they
      batch/shard/vmap exactly like the KV state they live next to.
    * ``kv_codec`` — a `serving.kvcache.KVCodec` with ``bits > 0``
      switches the scanned ``k``/``v`` stores to the quantized layout
      (``{k,v}_codes``/``{k,v}_scale``, see `serving.kvcache`):
      dequantize-on-attend, then encode only this step's fresh rows.
    """
    caches = dict(caches)
    pos0 = caches.pop("pos")
    hop_m = caches.pop("hop_m", None)
    boundary_state = {"m": hop_m} if hop_m is not None else None
    quant = (kv_codec is not None and kv_codec.bits
             and cfg.family in ("dense", "vlm", "moe", "audio"))
    h = embed_tokens(params, cfg, tokens, patches)
    b, s = h.shape[0], h.shape[1]
    positions = pos0 + jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32), (b, s))
    if "k" in caches:
        cache_len = caches["k"].shape[2]
    elif "k_codes" in caches:
        cache_len = caches["k_codes"].shape[2]
    else:
        cache_len = 0
    fam = cfg.family
    aux = 0.0
    new_caches = {"pos": pos0 + s}

    if fam == "audio" and frames is not None:    # (re)compute cross kv
        enc = encode_audio(params, cfg, frames, block_k=block_k)
        xk, xv = _cross_kv_all(params, cfg, enc)
        caches["xk"], caches["xv"] = (xk.astype(caches["xk"].dtype),
                                      xv.astype(caches["xv"].dtype))

    if fam in ("dense", "vlm", "moe", "audio"):
        n_scan = cfg.num_layers - cfg.first_dense_layers
        windows = window_vector(cfg, cache_len, n_scan,
                                cfg.first_dense_layers)
        for i, lp in enumerate(params.get("prefix", [])):
            h, (nk, nv), a = _attn_layer_cached(
                cfg, lp, h, positions, cfg.layer_window(i, cache_len),
                caches["pk"][i], caches["pv"][i], pos0, block_k)
            caches["pk"] = caches["pk"].at[i].set(nk)
            caches["pv"] = caches["pv"].at[i].set(nv)
            aux += a
        if cfg.first_dense_layers:
            new_caches["pk"], new_caches["pv"] = caches["pk"], caches["pv"]

        def step(carry, xs):
            hh, auxc = carry
            if quant:
                if fam == "audio":
                    lp, w, kc, ksc, vc, vsc, xk_l, xv_l = xs
                    xkv = (xk_l, xv_l)
                else:
                    lp, w, kc, ksc, vc, vsc = xs
                    xkv = None
                ck = kv_codec.decode(kc, ksc, cfg.jax_dtype)
                cv = kv_codec.decode(vc, vsc, cfg.jax_dtype)
            else:
                if fam == "audio":
                    lp, w, ck, cv, xk_l, xv_l = xs
                    xkv = (xk_l, xv_l)
                else:
                    lp, w, ck, cv = xs
                    xkv = None
            hh, (nk, nv), a = _attn_layer_cached(
                cfg, lp, hh, positions, w, ck, cv, pos0, block_k, xkv)
            if quant:
                # encode ONLY this step's fresh rows back into the code
                # store — old tokens keep their original single encoding
                fk = jax.lax.dynamic_slice_in_dim(nk, pos0, s, axis=1)
                fv = jax.lax.dynamic_slice_in_dim(nv, pos0, s, axis=1)
                sk = kv_codec.append({"codes": kc, "scale": ksc}, fk, pos0)
                sv = kv_codec.append({"codes": vc, "scale": vsc}, fv, pos0)
                return (hh, auxc + a), (sk["codes"], sk["scale"],
                                        sv["codes"], sv["scale"])
            return (hh, auxc + a), (nk, nv)

        if quant:
            xs = (params["layers"], windows,
                  caches["k_codes"], caches["k_scale"],
                  caches["v_codes"], caches["v_scale"])
        else:
            xs = (params["layers"], windows, caches["k"], caches["v"])
        if fam == "audio":
            xs = xs + (caches["xk"], caches["xv"])
        (h, aux2), ys, boundary_state = _staged_cached_scan(
            step, (h, 0.0), xs, num_stages=num_stages,
            boundary_fn=boundary_fn, boundary_state=boundary_state,
            get_h=lambda c: c[0], set_h=lambda c, hh: (hh, c[1]))
        aux += aux2
        if quant:
            (new_caches["k_codes"], new_caches["k_scale"],
             new_caches["v_codes"], new_caches["v_scale"]) = ys
        else:
            new_caches["k"], new_caches["v"] = ys
        if fam == "audio":
            new_caches["xk"], new_caches["xv"] = caches["xk"], caches["xv"]

    elif fam == "ssm":
        def step(hh, xs):
            lp, st, cv = xs
            hin = L.rmsnorm(lp["norm1"], hh, cfg.norm_eps)
            if s == 1:
                out, nst, ncv = S.mamba2_decode_step(
                    lp["mamba"], hin, cfg, st, cv)
            else:
                out, state = S.mamba2_forward(lp["mamba"], hin, cfg,
                                              initial_state=st)
                nst, ncv = state["ssm"], state["conv"].astype(cv.dtype)
            return hh + out, (nst.astype(st.dtype), ncv)

        h, (nst, ncv), boundary_state = _staged_cached_scan(
            step, h, (params["layers"], caches["ssm"], caches["conv"]),
            num_stages=num_stages, boundary_fn=boundary_fn,
            boundary_state=boundary_state,
            get_h=lambda c: c, set_h=lambda c, hh: hh)
        new_caches["ssm"], new_caches["conv"] = nst, ncv

    elif fam == "hybrid":
        per = cfg.shared_attn_every
        n_blocks = cfg.num_layers // per
        blocks = jax.tree.map(
            lambda a: a.reshape(n_blocks, per, *a.shape[1:]),
            params["layers"])
        sstates = caches["ssm"].reshape(n_blocks, per,
                                        *caches["ssm"].shape[1:])
        cstates = caches["conv"].reshape(n_blocks, per,
                                         *caches["conv"].shape[1:])

        def block_step(hh, xs):
            blk, sst, cst, ck, cv = xs

            def inner(c, ixs):
                lp, st, cvs = ixs
                hin = L.rmsnorm(lp["norm1"], c, cfg.norm_eps)
                if s == 1:
                    out, nst, ncv = S.mamba2_decode_step(
                        lp["mamba"], hin, cfg, st, cvs)
                else:
                    out, state = S.mamba2_forward(lp["mamba"], hin, cfg,
                                                  initial_state=st)
                    nst = state["ssm"]
                    ncv = state["conv"].astype(cvs.dtype)
                return c + out, (nst.astype(st.dtype), ncv)

            hh, (nst, ncv) = jax.lax.scan(inner, hh, (blk, sst, cst))
            hh, (nk, nv), _ = _attn_layer_cached(
                cfg, params["shared_block"], hh, positions,
                cfg.sliding_window or cache_len, ck, cv, pos0, block_k)
            return hh, (nst, ncv, nk, nv)

        h, (nst, ncv, nk, nv), boundary_state = _staged_cached_scan(
            block_step, h,
            (blocks, sstates, cstates, caches["k"], caches["v"]),
            num_stages=num_stages, boundary_fn=boundary_fn,
            boundary_state=boundary_state,
            get_h=lambda c: c, set_h=lambda c, hh: hh)
        new_caches["ssm"] = nst.reshape(caches["ssm"].shape)
        new_caches["conv"] = ncv.reshape(caches["conv"].shape)
        new_caches["k"], new_caches["v"] = nk, nv
    else:
        raise ValueError(fam)

    if hop_m is not None:
        new_caches["hop_m"] = boundary_state["m"]
    if patches is not None:
        h = h[:, patches.shape[1]:]
    if logits_last_only:
        h = h[:, -1:]
    logits = lm_logits(params, cfg, h)
    return logits, new_caches


def _cross_kv_all(params, cfg: ModelConfig, enc_out):
    """The audio decoder consumes the same encoder memory at every layer;
    we pass raw (k=v=enc projections) per layer inside the scan instead of
    stacking L copies — here we just return the encoder output and let the
    layer project it (cheap: S_enc=1500)."""
    # project per layer inside the scan: attention() receives cross_kv as
    # (k, v) *after* head reshape; we defer projection by passing enc_out
    # through a closure — see _attn_ffn cross path.  To keep the scan
    # homogeneous we project here with the *stacked* per-layer weights.
    wk = params["layers"]["xattn"]["wk"]        # (L, d, Hk*hd)
    wv = params["layers"]["xattn"]["wv"]
    b, se, d = enc_out.shape
    k = jnp.einsum("bsd,ldh->lbsh", enc_out, wk.astype(enc_out.dtype))
    v = jnp.einsum("bsd,ldh->lbsh", enc_out, wv.astype(enc_out.dtype))
    hk, hd = cfg.num_kv_heads, cfg.head_dim
    return (k.reshape(cfg.num_layers, b, se, hk, hd),
            v.reshape(cfg.num_layers, b, se, hk, hd))
