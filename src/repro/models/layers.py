"""Shared transformer building blocks (pure JAX).

Design constraints that shape this file:

* **Scan-homogeneous layers** — the pipeline runtime stacks per-layer
  params and scans/shards them, so layer variation (sliding window,
  local/global alternation) is expressed as *per-layer data* (a window
  scalar), never as structural differences.
* **Blockwise attention** — prefill_32k would need O(S²) score
  materialization with naive attention (TBs at full scale); we use an
  online-softmax blockwise formulation (lax.scan over KV blocks) so the
  full-scale dry-runs fit HBM.  Decode (S_q = 1) uses single-shot scores.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1.0e9


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"].astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embeddings.  x: (B, S, H, hd); positions: (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.exp(-math.log(theta) *
                   jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Attention (GQA + sliding window + softcap), blockwise for S_q > 1
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int):
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    so = 1.0 / math.sqrt(num_heads * head_dim)
    return {
        "wq": jax.random.normal(kq, (d_model, num_heads * head_dim)) * s,
        "wk": jax.random.normal(kk, (d_model, num_kv_heads * head_dim)) * s,
        "wv": jax.random.normal(kv, (d_model, num_kv_heads * head_dim)) * s,
        "wo": jax.random.normal(ko, (num_heads * head_dim, d_model)) * so,
    }


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, Hk, hd) -> (B, S, Hk*groups, hd)."""
    if groups == 1:
        return k
    b, s, hk, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, hk, groups, hd))
    return k.reshape(b, s, hk * groups, hd)


def blockwise_attention(q, k, v, *, q_pos, k_pos, window, causal=True,
                        attn_softcap=0.0, block_k=512):
    """Online-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, H, hd) (kv already head-repeated).
    q_pos: (B, Sq) int32; k_pos: (B, Sk) int32.
    window: scalar (may be traced) — key j visible to query i iff
            j <= i (causal) and j > i - window.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    qf = (q * scale).astype(jnp.float32).transpose(0, 2, 1, 3)  # (B,H,Sq,hd)

    nblk = -(-sk // block_k)
    pad = nblk * block_k - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-10**9)
    kb = k.transpose(0, 2, 1, 3).reshape(b, h, nblk, block_k, hd)
    vb = v.transpose(0, 2, 1, 3).reshape(b, h, nblk, block_k, hd)
    kpb = k_pos.reshape(b, nblk, block_k)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, kp = blk                       # (B,H,bk,hd),(B,H,bk,hd),(B,bk)
        s_blk = jnp.einsum("bhqd,bhkd->bhqk", qf,
                           kblk.astype(jnp.float32))
        s_blk = softcap(s_blk, attn_softcap)
        vis = kp[:, None, None, :] <= q_pos[:, None, :, None] \
            if causal else jnp.ones_like(s_blk, dtype=bool)
        vis &= kp[:, None, None, :] > (q_pos[:, None, :, None] - window)
        s_blk = jnp.where(vis, s_blk, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
        p = jnp.exp(s_blk - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, h, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, h, sq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        step, init,
        (kb.transpose(2, 0, 1, 3, 4), vb.transpose(2, 0, 1, 3, 4),
         kpb.transpose(1, 0, 2)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)   # (B,Sq,H,hd)


# ---------------------------------------------------------------------------
# Flash attention (custom_vjp): O(S) residuals — the blockwise forward
# above saves per-block probabilities under AD (TBs at 32k); this variant
# saves only (o, lse) and re-streams KV blocks in the backward pass.
# ---------------------------------------------------------------------------

def _flash_fwd_scan(qf, kb, vb, kpb, q_pos, *, window, causal, cap):
    """qf: (B,H,Sq,hd) f32 pre-scaled; kb/vb: (nblk,B,H,bk,hd);
    kpb: (nblk,B,bk).  Returns (out f32, m, l)."""
    b, h, sq, hd = qf.shape

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, kp = blk
        s_blk = jnp.einsum("bhqd,bhkd->bhqk", qf,
                           kblk.astype(jnp.float32))
        s_blk = softcap(s_blk, cap)
        vis = kp[:, None, None, :] <= q_pos[:, None, :, None] \
            if causal else jnp.ones_like(s_blk, dtype=bool)
        vis &= kp[:, None, None, :] > (q_pos[:, None, :, None] - window)
        s_blk = jnp.where(vis, s_blk, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
        p = jnp.exp(s_blk - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, h, sq), NEG_INF, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, h, sq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, (kb, vb, kpb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out, m, l


@functools.lru_cache(maxsize=None)
def _make_flash(causal: bool, cap: float, block_k: int):
    @jax.custom_vjp
    def flash(q, k, v, q_pos, k_pos, window):
        return _fwd(q, k, v, q_pos, k_pos, window)[0]

    def _prep(q, k, v, k_pos):
        b, sq, h, hd = q.shape
        sk = k.shape[1]
        scale = 1.0 / math.sqrt(hd)
        qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)
        nblk = -(-sk // block_k)
        pad = nblk * block_k - sk
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)),
                            constant_values=-10 ** 9)
        kb = k.transpose(0, 2, 1, 3).reshape(
            b, h, nblk, block_k, hd).transpose(2, 0, 1, 3, 4)
        vb = v.transpose(0, 2, 1, 3).reshape(
            b, h, nblk, block_k, hd).transpose(2, 0, 1, 3, 4)
        kpb = k_pos.reshape(b, nblk, block_k).transpose(1, 0, 2)
        return qf, kb, vb, kpb, pad

    def _fwd(q, k, v, q_pos, k_pos, window):
        qf, kb, vb, kpb, _ = _prep(q, k, v, k_pos)
        out, m, l = _flash_fwd_scan(qf, kb, vb, kpb, q_pos,
                                    window=window, causal=causal, cap=cap)
        o = out.transpose(0, 2, 1, 3).astype(q.dtype)   # (B,Sq,H,hd)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))        # (B,H,Sq)
        return o, (q, k, v, q_pos, k_pos, window, o, lse)

    def _bwd(res, g):
        q, k, v, q_pos, k_pos, window, o, lse = res
        b, sq, h, hd = q.shape
        sk = k.shape[1]
        scale = 1.0 / math.sqrt(hd)
        qf = q.astype(jnp.float32).transpose(0, 2, 1, 3)      # (B,H,Sq,hd)
        gf = g.astype(jnp.float32).transpose(0, 2, 1, 3)
        of = o.astype(jnp.float32).transpose(0, 2, 1, 3)
        delta = jnp.sum(gf * of, axis=-1)                     # (B,H,Sq)
        _, kb, vb, kpb, pad = _prep(q, k, v, k_pos)

        def step(dq, blk):
            kblk, vblk, kp = blk
            kf = kblk.astype(jnp.float32)
            vf = vblk.astype(jnp.float32)
            u = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
            if cap > 0.0:
                s = cap * jnp.tanh(u / cap)
                dsdu = 1.0 - jnp.square(s / cap)
            else:
                s, dsdu = u, 1.0
            vis = kp[:, None, None, :] <= q_pos[:, None, :, None] \
                if causal else jnp.ones_like(s, dtype=bool)
            vis &= kp[:, None, None, :] > (q_pos[:, None, :, None]
                                           - window)
            s = jnp.where(vis, s, NEG_INF)
            p = jnp.exp(s - lse[..., None])                   # (B,H,Sq,bk)
            dv = jnp.einsum("bhqk,bhqd->bhkd", p, gf)
            dp = jnp.einsum("bhqd,bhkd->bhqk", gf, vf)
            ds = p * (dp - delta[..., None]) * dsdu
            ds = jnp.where(vis, ds, 0.0)
            dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kf) * scale
            dk = jnp.einsum("bhqk,bhqd->bhkd", ds, qf) * scale
            return dq, (dk, dv)

        dq0 = jnp.zeros_like(qf)
        dq, (dk_b, dv_b) = jax.lax.scan(step, dq0, (kb, vb, kpb))
        nblk = kb.shape[0]
        dk = dk_b.transpose(1, 2, 0, 3, 4).reshape(b, h, nblk * block_k,
                                                   hd)
        dv = dv_b.transpose(1, 2, 0, 3, 4).reshape(b, h, nblk * block_k,
                                                   hd)
        if pad:
            dk, dv = dk[:, :, :sk], dv[:, :, :sk]
        dq_out = dq.transpose(0, 2, 1, 3).astype(q.dtype)
        dk_out = dk.transpose(0, 2, 1, 3).astype(k.dtype)
        dv_out = dv.transpose(0, 2, 1, 3).astype(v.dtype)
        f0 = jax.dtypes.float0
        return (dq_out, dk_out, dv_out,
                np.zeros(q_pos.shape, f0), np.zeros(k_pos.shape, f0),
                np.zeros(window.shape, f0))

    flash.defvjp(_fwd, _bwd)
    return flash


def flash_attention(q, k, v, *, q_pos, k_pos, window, causal=True,
                    attn_softcap=0.0, block_k=512, block_q=2048):
    """Memory-lean attention used on all training/prefill paths.
    window may be a traced per-layer scalar (scan homogeneity).

    Q is chunked with lax.map when Sq > block_q: without it a 32k prefill
    materializes (B, H, Sq, block_k) f32 score tiles (~13 GB on mixtral).
    """
    fn = _make_flash(bool(causal), float(attn_softcap), int(block_k))
    w = jnp.asarray(window, jnp.int32)
    sq = q.shape[1]
    if sq <= block_q or sq % block_q:
        return fn(q, k, v, q_pos, k_pos, w)
    nq = sq // block_q

    def chunk(args):
        qc, pc = args
        return fn(qc, k, v, pc, k_pos, w)

    qs = jnp.moveaxis(q.reshape(q.shape[0], nq, block_q, *q.shape[2:]),
                      1, 0)
    ps = jnp.moveaxis(q_pos.reshape(q_pos.shape[0], nq, block_q), 1, 0)
    out = jax.lax.map(chunk, (qs, ps))
    return jnp.moveaxis(out, 0, 1).reshape(q.shape)


def onehot_attention(q, k, v, *, q_pos, k_pos, window, causal=True,
                     attn_softcap=0.0):
    """Single-shot attention for decode (S_q small).

    GQA-aware: k/v may have fewer heads than q (H = Hk * G) — the shared
    kv heads are used in-place, never materialized repeated (a 0.5M-token
    cache repeated 2-4x would dominate decode HBM)."""
    b, sq, h, hd = q.shape
    hk = k.shape[2]
    g = h // hk
    scale = 1.0 / math.sqrt(hd)
    qg = (q * scale).astype(jnp.float32).reshape(b, sq, hk, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = softcap(s, attn_softcap)
    vis = k_pos[:, None, None, None, :] <= q_pos[:, None, None, :, None] \
        if causal else jnp.ones_like(s, dtype=bool)
    vis &= k_pos[:, None, None, None, :] > \
        (q_pos[:, None, None, :, None] - window)
    s = jnp.where(vis, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


BIG_WINDOW = 10 ** 9


def attention(p, x, *, num_heads, num_kv_heads, head_dim, rope_theta,
              positions, window, causal=True, attn_softcap=0.0,
              kv_cache=None, cache_index=None, cross_kv=None,
              block_k=512):
    """Full attention sublayer.  x: (B, S, d).

    kv_cache: optional dict {k: (B, Sc, Hk, hd), v: ...} — decode mode:
      new kv written at cache_index, attention runs over the cache.
    cross_kv: optional precomputed (k, v) from an encoder (no causal mask,
      no rope on kv) — whisper cross-attention.
    """
    b, s, _ = x.shape
    dtype = x.dtype
    q = (x @ p["wq"].astype(dtype)).reshape(b, s, num_heads, head_dim)

    if cross_kv is not None:
        k, v = cross_kv
        k_pos = jnp.zeros((b, k.shape[1]), jnp.int32)
        causal = False
        window = BIG_WINDOW
    else:
        k = (x @ p["wk"].astype(dtype)).reshape(b, s, num_kv_heads, head_dim)
        v = (x @ p["wv"].astype(dtype)).reshape(b, s, num_kv_heads, head_dim)
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
        if kv_cache is not None:
            # decode: scatter new kv at cache_index, attend over cache
            k = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), cache_index, 1)
            v = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), cache_index, 1)
            kv_cache = {"k": k, "v": v}
            sc = k.shape[1]
            k_pos = jnp.broadcast_to(jnp.arange(sc, dtype=jnp.int32), (b, sc))
            # positions beyond the write head are invisible (<= q_pos check
            # handles it since they hold garbage but pos > q_pos).
        else:
            k_pos = positions
    if s == 1:
        # decode: GQA handled inside (no repeated cache materialization)
        out = onehot_attention(q, k, v, q_pos=positions, k_pos=k_pos,
                               window=window, causal=causal,
                               attn_softcap=attn_softcap)
    else:
        if cross_kv is None:
            groups = num_heads // num_kv_heads
            k = _repeat_kv(k, groups)
            v = _repeat_kv(v, groups)
        out = flash_attention(q, k, v, q_pos=positions, k_pos=k_pos,
                              window=window, causal=causal,
                              attn_softcap=attn_softcap, block_k=block_k)
    out = out.reshape(b, s, num_heads * head_dim)
    out = out @ p["wo"].astype(dtype)
    return (out, kv_cache) if kv_cache is not None else (out, None)


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU/GeGLU or plain)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    p = {"w_up": jax.random.normal(k2, (d_model, d_ff)) * s_in,
         "w_down": jax.random.normal(k3, (d_ff, d_model)) * s_out}
    if gated:
        p["w_gate"] = jax.random.normal(k1, (d_model, d_ff)) * s_in
    return p


def mlp(p, x, act: str = "silu"):
    dtype = x.dtype
    fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    up = x @ p["w_up"].astype(dtype)
    if "w_gate" in p:
        up = fn(x @ p["w_gate"].astype(dtype)) * up
    else:
        up = fn(up)
    return up @ p["w_down"].astype(dtype)
