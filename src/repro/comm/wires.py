"""Named wire registry: every inter-machine byte class, one table.

A *wire* is a named way of moving one plane's payload between machines
(or, for the z-buffer plane, into HBM): the activation ``ppermute``
boundary crossings, the three DP gradient collectives, and any wire a
later PR registers.  Each entry is a :class:`WireSpec` carrying

* ``plane`` — which communication plane it serves
  (``fw-activation`` / ``bw-gradient`` / ``z-buffer`` / ``dp-grad``);
* ``summary`` — the one-liner CLI help and ``--list-wires`` print
  (the single source; `launch/train.py` generates its ``--dp-wire``
  help from it, so the help text can no longer drift from the
  registry);
* ``wire_bytes(shape, bits, n)`` — the uniform byte-accounting model.
  For DP wires it is EXACT: tests/test_hlo_cost.py pins it against the
  collective bytes `launch/hlo_cost.py` counts in the compiled HLO,
  for EVERY registered DP wire (registry completeness is enforced —
  a wire cannot land without a pinned byte model);
* for DP wires, the shard_map ``collective`` and its bit-faithful
  simulator ``sim_allreduce`` (``sharded=True`` marks the ZeRO wire
  whose result is one owned segment per rank);
* for DP wires, ``expected_collectives(shape, bits, n)`` — the wire's
  *communication-graph manifest*: every collective its compiled HLO is
  allowed to contain, as ``(kind, dtype, bytes_per_op, count)`` rows.
  `repro.analysis.collectives` compiles each wire on the standard
  4-device ring and diffs the measured inventory against this
  manifest, so a GSPMD-inserted extra collective (the PR-4 bug class)
  or an f32 all-reduce smuggled onto a compressed path fails loudly
  (``python -m repro.analysis``, gated in CI).

`register_wire` is how new wires land: the ROADMAP's autodiff-hoist
wire, topk, or further passthroughs become registry entries instead of
another `training/pipeline.py` surgery.  The ``fp16`` wire below is
the proof: a passthrough `core/collectives.py` never special-cased,
trained end-to-end through `launch.train --dp-wire fp16` with no
trainer changes.
"""
from __future__ import annotations

import difflib
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import collectives as C
from repro.core import grad_compress as GC
from repro.core import quantization as Q

PLANES = ("fw-activation", "bw-gradient", "z-buffer", "dp-grad",
          "kv-cache")


@dataclass(frozen=True)
class WireSpec:
    """One registered wire: identity, help text, byte model, and (for
    DP wires) the collective + simulator that carry it.

    ``wire_bytes(shape, bits, n)`` returns the bytes this wire puts on
    the network (or, ``network=False``, into HBM) for one payload of
    ``shape`` at ``bits`` over an ``n``-rank group — per device per
    crossing, matching what `launch/hlo_cost.py` measures."""
    name: str
    plane: str
    summary: str
    wire_bytes: Callable[[tuple, int, int], int]
    collective: Optional[Callable] = None     # shard_map body (dp-grad)
    sim_allreduce: Optional[Callable] = None  # bit-/math-faithful sim
    expected_collectives: Optional[Callable] = None
                                              # (shape, bits, n) ->
                                              # [(kind, dtype, bytes,
                                              #   count)] manifest the
                                              # HLO auditor pins
    sharded: bool = False                     # ZeRO: one segment/rank
    network: bool = True                      # False: HBM plane
    chunkable: bool = False                   # accepts a chunks= kwarg:
                                              # K-chunk double-buffered
                                              # schedule, bit- and
                                              # byte-identical to K=1
                                              # (ring-family wires only)
    psum_lowered: bool = False                # single psum collective:
                                              # the byte model counts
                                              # logical lanes, so ring-
                                              # allreduce physical-cost
                                              # models apply a 2x on top
                                              # (ring wires count their
                                              # own hops instead)
    internal: bool = False                    # hidden from wire_names /
                                              # list_wires enumeration:
                                              # harness-owned wrappers
                                              # (fault injection), not
                                              # user-selectable wires


_REGISTRY: dict[tuple[str, str], WireSpec] = {}


def register_wire(name: str, *, summary: str, wire_bytes,
                  plane: str = "dp-grad", collective=None,
                  sim_allreduce=None, expected_collectives=None,
                  sharded: bool = False,
                  network: bool = True, chunkable: bool = False,
                  psum_lowered: bool = False,
                  internal: bool = False) -> WireSpec:
    """Register a wire under ``(plane, name)``; names are unique per
    plane.  Returns the spec (so modules can keep a handle).
    ``chunkable=True`` declares the collective accepts a ``chunks=``
    kwarg (the K-chunk double-buffered schedule) — `CommConfig`
    validates ``dp.chunks`` against this flag.  ``internal=True``
    registers a harness-owned wrapper (e.g. `repro.comm.faults` fault
    wires): resolvable by `get_wire` but hidden from `wire_names` /
    `list_wires`, so CLI help, ``--list-wires``, and the registry-
    completeness byte-model gates never see it.

    ``expected_collectives`` is the wire's communication-graph
    manifest for the `repro.analysis.collectives` auditor (see the
    module docstring); the ``registry-completeness`` lint rule
    requires it on every non-internal collective wire."""
    assert plane in PLANES, plane
    key = (plane, name)
    if key in _REGISTRY:
        raise ValueError(f"wire {name!r} already registered on plane "
                         f"{plane!r}")
    spec = WireSpec(name=name, plane=plane, summary=summary,
                    wire_bytes=wire_bytes, collective=collective,
                    sim_allreduce=sim_allreduce,
                    expected_collectives=expected_collectives,
                    sharded=sharded,
                    network=network, chunkable=chunkable,
                    psum_lowered=psum_lowered, internal=internal)
    _REGISTRY[key] = spec
    return spec


def unknown_wire_message(name: str, plane: str) -> str:
    """Error text for an unknown wire, with a did-you-mean hint."""
    known = wire_names(plane)
    msg = (f"unknown wire {name!r} on plane {plane!r}; "
           f"registered wires: {', '.join(known)}")
    close = difflib.get_close_matches(name, known, n=1, cutoff=0.5)
    if close:
        msg += f" — did you mean {close[0]!r}?"
    return msg


def get_wire(name: str, plane: str = "dp-grad") -> WireSpec:
    """Look a wire up by name (plane defaults to the DP gradient plane,
    the one with interchangeable wires).  Unknown names raise with a
    did-you-mean message."""
    spec = _REGISTRY.get((plane, name))
    if spec is None:
        raise ValueError(unknown_wire_message(name, plane))
    return spec


def wire_names(plane: Optional[str] = None, *,
               include_internal: bool = False) -> list[str]:
    """Registered wire names, registration order (optionally filtered
    to one plane).  Internal wrapper wires (fault injection) are
    hidden unless ``include_internal=True``."""
    return [s.name for s in list_wires(plane,
                                       include_internal=include_internal)]


def list_wires(plane: Optional[str] = None, *,
               include_internal: bool = False) -> list[WireSpec]:
    """All registered specs, registration order.  Internal wrapper
    wires (fault injection) are hidden unless
    ``include_internal=True``."""
    return [s for (p, _), s in _REGISTRY.items()
            if (plane is None or p == plane)
            and (include_internal or not s.internal)]


# ---------------------------------------------------------------------------
# byte models (shape, bits, n) -> int.  DP models are exact per device
# per step — pinned against compiled HLO by tests/test_hlo_cost.py.
# ---------------------------------------------------------------------------

def _codec_bytes(shape, bits: int, n: int = 1) -> int:
    """Packed b-bit codes + one f32 scale per row: the boundary payload
    (`Q.wire_bytes`) — forward deltas, backward gradients, z-buffers."""
    del n
    return Q.wire_bytes(shape, bits)


def _psum_bytes(shape, bits: int, n: int = 1) -> int:
    """i32 code lanes in one psum + the f32 scale pmax (the
    conservative baseline the ring wires improve on)."""
    del bits, n
    rows, d = shape
    return rows * d * 4 + rows * 4


def _ring_bytes(shape, bits: int, n: int = 2) -> int:
    return C.ring_wire_bytes(shape, bits, n=n)


def _ring_sharded_bytes(shape, bits: int, n: int = 2) -> int:
    return C.ring_wire_bytes(shape, bits, n=n, sharded=True)


def _fp16_bytes(shape, bits: int, n: int = 1) -> int:
    """f16 lanes in one psum; no codes, no scales, no bits knob."""
    del bits, n
    rows, d = shape
    return rows * d * 2


def _kv_bytes(shape, bits: int, n: int = 1) -> int:
    """Stored bytes of one quantized KV append: packed b-bit codes plus
    one f32 scale per quantization group.  ``shape`` is the GROUPED
    value shape ``(..., group)`` — `serving.kvcache.KVCodec` reshapes
    ``(B, S, Hk, head_dim)`` values into scale groups before encoding,
    so the rows of this model are (token, head, group) triples.
    ``bits=0`` means the cache is raw f32 (no codes, no scales).
    Pinned against the output buffers of the compiled append op by
    tests/test_hlo_cost.py (HBM residency, like the z-buffer plane)."""
    del n
    if not bits:
        import numpy as np
        return int(np.prod(shape)) * 4
    return Q.wire_bytes(shape, bits)


# ---------------------------------------------------------------------------
# expected-collective manifests (shape, bits, n) -> [(kind, dtype,
# bytes_per_op, count)].  The communication graph each DP wire is
# ALLOWED to compile to — `repro.analysis.collectives` diffs the
# measured HLO inventory against these rows, and checks each
# manifest's total against the wire_bytes model above, so neither can
# drift.  Counts are per device per step on an n-rank ring.
# ---------------------------------------------------------------------------

def _scale_pmax(shape) -> tuple:
    """The one collective every codec wire shares: the f32 per-row
    scale ``pmax`` (rows * 4 B in a single all-reduce)."""
    rows, _ = shape
    return ("all-reduce", "f32", rows * 4, 1)


def _ring_manifest(shape, bits: int, n: int):
    """Full compressed ring: n-1 packed b-bit code-segment hops
    (reduce-scatter half) + n-1 packed code-SUM segment hops at
    b + ceil(log2 n) bits (all-gather half) + the scale pmax."""
    rows, d = shape
    seg = C.ring_segment_rows(rows, n)
    return [
        _scale_pmax(shape),
        ("collective-permute", "u8", seg * Q.packed_width(d, bits),
         n - 1),
        ("collective-permute", "u8",
         seg * Q.sum_packed_width(d, bits, n), n - 1),
    ]


def _ring_sharded_manifest(shape, bits: int, n: int):
    """ZeRO wire: the ring stopped at its reduce-scatter midpoint —
    only the n-1 packed code hops and the scale pmax; any other
    collective here is the GSPMD-inserted bug class."""
    rows, d = shape
    seg = C.ring_segment_rows(rows, n)
    return [
        _scale_pmax(shape),
        ("collective-permute", "u8", seg * Q.packed_width(d, bits),
         n - 1),
    ]


def _psum_manifest(shape, bits: int, n: int):
    """i32-lane baseline: one s32 code all-reduce + the scale pmax."""
    del bits, n
    rows, d = shape
    return [_scale_pmax(shape), ("all-reduce", "s32", rows * d * 4, 1)]


def _fp16_manifest(shape, bits: int, n: int):
    """Passthrough: exactly one f16 all-reduce — no codes, no scales;
    an f32 all-reduce appearing here would mean the cast was elided."""
    del bits, n
    rows, d = shape
    return [("all-reduce", "f16", rows * d * 2, 1)]


# ---------------------------------------------------------------------------
# the fp16 passthrough DP wire — the registry-only wire: nothing in
# core/collectives.py special-cases it, yet it trains end-to-end
# ---------------------------------------------------------------------------

def fp16_mean_bucket(v_grad, err, axis_name, bits: int, key,
                     *, stochastic: bool = True, backend: str = "auto"):
    """fp16-passthrough compressed allreduce of one gradient bucket:
    the compensated bucket ships as raw float16 lanes in a single
    ``psum`` — half the fp32 bytes, no codes, no scales, no noise.

    Same signature as the codec wires (`ef_psum_mean_bucket` etc.) so
    the registry closes over it; ``bits``/``key``/``stochastic``/
    ``backend`` are accepted and ignored (the cast is deterministic).
    Error feedback carries the local cast error ``v - f32(f16(v))`` —
    the standard EF form for a deterministic compressor.  Unlike the
    int32 code wires, f16 summation is order-dependent, so NO bit
    parity with the simulator is claimed (which is exactly why the
    codec wires exist); `fp16_sim_allreduce` is math-faithful only.
    Must run inside shard_map over ``axis_name``."""
    del bits, key, stochastic, backend
    n = jax.lax.psum(1, axis_name)
    v = v_grad.astype(jnp.float32) + err
    h = v.astype(jnp.float16)
    new_err = v - h.astype(jnp.float32)
    mean = jax.lax.psum(h, axis_name).astype(jnp.float32) / n
    return mean, new_err


def fp16_sim_allreduce(grads_list, error_state, bits: int, key,
                       *, stochastic: bool = True, backend: str = "auto",
                       layout=None):
    """Single-process simulation of `fp16_mean_bucket` over n workers
    (same signature as `grad_compress.compress_allreduce`).  Math-
    faithful, not bit-faithful: f16 sums are order-dependent on the
    wire (see `fp16_mean_bucket`)."""
    del bits, key, stochastic, backend
    n = len(grads_list)
    lay = layout or GC.bucket_layout(grads_list[0])
    v = jnp.stack([GC.flatten_bucket(g, lay) for g in grads_list]) \
        + error_state
    h = v.astype(jnp.float16)
    new_err = v - h.astype(jnp.float32)
    total = jnp.sum(h, axis=0, dtype=jnp.float16)
    mean = total.astype(jnp.float32) / n
    return GC.unflatten_bucket(mean, lay, grads_list[0]), new_err


# ---------------------------------------------------------------------------
# built-in registrations
# ---------------------------------------------------------------------------

register_wire(
    "ppermute", plane="fw-activation",
    summary="packed AQ-SGD delta / DirectQ codes + f32 row scales on "
            "the pipeline collective-permute",
    wire_bytes=_codec_bytes)
register_wire(
    "ppermute", plane="bw-gradient",
    summary="packed DirectQ gradient codes + scales on the reverse "
            "collective-permute (the transfer custom_vjp)",
    wire_bytes=_codec_bytes)
register_wire(
    "hbm", plane="z-buffer", network=False,
    summary="z-bit stored message buffers (paper §H.5): HBM residency, "
            "not network bytes",
    wire_bytes=_codec_bytes)

register_wire(
    "paged", plane="kv-cache", network=False,
    summary="b-bit packed KV codes + f32 group scales in paged "
            "per-request HBM cache slots (quantize-on-append, "
            "dequantize-on-attend)",
    wire_bytes=_kv_bytes)

register_wire(
    "ring", chunkable=True,
    summary="packed b-bit code segments on rotation ppermute hops + "
            "packed code sums (bandwidth-optimal; bit-identical to "
            "psum)",
    wire_bytes=_ring_bytes,
    collective=C.ring_ef_reduce_mean_bucket,
    sim_allreduce=GC.compress_allreduce,
    expected_collectives=_ring_manifest)
register_wire(
    "psum", psum_lowered=True,
    summary="int32 code lanes in one psum (conservative baseline; "
            "bit-identical to ring)",
    wire_bytes=_psum_bytes,
    collective=C.ef_psum_mean_bucket,
    sim_allreduce=GC.compress_allreduce,
    expected_collectives=_psum_manifest)
register_wire(
    "ring-sharded", sharded=True, chunkable=True,
    summary="ZeRO wire: the ring's reduce-scatter half only, "
            "segment-owner optimizer, f32 updated-parameter all-gather",
    wire_bytes=_ring_sharded_bytes,
    collective=C.ring_ef_reduce_scatter_bucket,
    sim_allreduce=GC.compress_reduce_scatter,
    expected_collectives=_ring_sharded_manifest)
register_wire(
    "fp16", psum_lowered=True,
    summary="raw float16 gradient lanes in one psum (passthrough "
            "baseline: no codes/scales/error-feedback telescoping "
            "guarantees; bits knob ignored)",
    wire_bytes=_fp16_bytes,
    collective=fp16_mean_bucket,
    sim_allreduce=fp16_sim_allreduce,
    expected_collectives=_fp16_manifest)
