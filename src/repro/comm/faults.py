"""Deterministic fault injection + payload guards for every plane.

The paper's setting (slow, decentralized, preemptible networks) makes
corrupt payloads a WHEN, not an IF — and stateful compression makes
them worse: a NaN that reaches the `dp_error` EF carry or the AQ-SGD
message buffers poisons every later step through the telescoping sum.
This module provides both halves of the defense:

**Injection** — a :class:`FaultPlan` of ``(step, plane, kind)``
coordinates, parsed from the CLI (``--fault 3:dp:nan-scale``).  Three
kinds, each the post-decode effect of a real wire failure:

* ``corrupt-codes`` — garbage packed codes: the decoded payload turns
  into huge finite values (±1e32);
* ``nan-scale``     — a NaN/Inf row scale: the decode is NaN;
* ``drop-hop``      — a zeroed ppermute hop: the payload is silently
  all-zero (finite AND small — the nasty one).

DP faults use the registry pattern itself: `fault_wire` registers an
INTERNAL wrapper wire (``ring+fault-nan-scale``) whose collective /
simulator delegate to the base wire and corrupt the decoded mean, and
`faulted_comm` swaps it into ``comm.dp.wire`` for exactly the fault
step.  Because the trainer configs hash the wire NAME, the fault step
compiles its own executable and every clean step reuses the original
one — injection cannot perturb clean-step bits.  fw / bw / zbuf
faults corrupt the carried state between steps (`inject_sim_state`);
kv faults poison one serving slot (`serving.batcher`).

**Guards** — two layers, because XLA cannot raise mid-graph:

* in-graph: `guard_dp_pair` NaN-poisons the decoded DP mean AND the
  EF carry when the mean is non-finite, implausibly huge
  (> ``GUARD_MAX``), or all-zero (the drop-hop sentinel).  On clean
  payloads the ``where`` selects the input elementwise — bit-exact,
  so every bit-parity gate in the suite is unaffected;
* host-side: `check_train_state` scans the post-step state and raises
  a structured :class:`WireFaultError` naming plane, wire, and step.
  Attribution is by which state a plane can reach, in dependency
  order: message buffers → zbuf if ``zbuf.bits`` else fw (written
  from the forward pass, unreachable by a later DP decode);
  ``dp_error`` → dp; params / opt / loss → bw if ``bw.bits`` else dp
  if ``dp.bits`` else fw.

`launch.runner` catches the error and replays from the last good
checkpoint (bounded retries); `serving.batcher` evicts the poisoned
slot via `slot_flags` while vmapped row independence keeps the
surviving slots bit-identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import wires as W

FAULT_KINDS = ("corrupt-codes", "nan-scale", "drop-hop")
# drop-hop's zero sentinel only works where an all-zero payload is
# implausible: the DP gradient mean and the seen rows of the message
# buffers.  bw gradients and kv cache rows can be legitimately zero.
ALLOWED_KINDS = {
    "dp": FAULT_KINDS, "fw": FAULT_KINDS, "zbuf": FAULT_KINDS,
    "bw": ("corrupt-codes", "nan-scale"),
    "kv": ("corrupt-codes", "nan-scale"),
}
GUARD_MAX = 1e30   # |value| above this is declared corrupt: far above
                   # any trained tensor, far below corrupt-codes' 1e32


class WireFaultError(RuntimeError):
    """A guard detected a corrupt payload.  Carries the structured
    coordinates (``plane``, ``wire``, ``step``, ``detail``) so the
    recovery loop and the tests can assert on WHAT was caught, not
    just that something raised."""

    def __init__(self, *, plane: str, wire: str, step: int,
                 detail: str):
        self.plane, self.wire = plane, wire
        self.step, self.detail = step, detail
        super().__init__(f"wire fault detected: plane={plane} "
                         f"wire={wire!r} step={step}: {detail}")


@dataclass(frozen=True)
class FaultSpec:
    """One injected fault: at training step ``step`` (0-based; for the
    kv plane, the batcher tick), on ``plane`` (a `CommConfig` plane
    field name: fw/bw/zbuf/dp/kv), of ``kind`` (`FAULT_KINDS`)."""
    step: int
    plane: str
    kind: str

    def __post_init__(self):
        if self.plane not in ALLOWED_KINDS:
            raise ValueError(f"unknown fault plane {self.plane!r}; "
                             f"one of {sorted(ALLOWED_KINDS)}")
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")
        if self.kind not in ALLOWED_KINDS[self.plane]:
            raise ValueError(
                f"kind {self.kind!r} is not injectable on plane "
                f"{self.plane!r} (an all-zero payload is legitimate "
                f"there); allowed: {ALLOWED_KINDS[self.plane]}")
        if self.step < 0:
            raise ValueError(f"fault step {self.step} < 0")

    def text(self) -> str:
        """The ``step:plane:kind`` CLI token for this fault."""
        return f"{self.step}:{self.plane}:{self.kind}"


@dataclass(frozen=True)
class FaultPlan:
    """A deterministic schedule of injected faults (possibly empty).
    Built from CLI text by `parse`; queried per step by `at`."""
    faults: tuple = ()

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Parse ``step:plane:kind[,step:plane:kind...]`` (the
        ``--fault`` flag).  Empty text = no faults.  Bad tokens raise
        with the expected grammar."""
        faults = []
        for tok in filter(None, (t.strip() for t in text.split(","))):
            parts = tok.split(":")
            if len(parts) != 3 or not parts[0].lstrip("-").isdigit():
                raise ValueError(
                    f"bad fault token {tok!r}: expected "
                    f"step:plane:kind, e.g. 3:dp:nan-scale")
            faults.append(FaultSpec(step=int(parts[0]), plane=parts[1],
                                    kind=parts[2]))
        return cls(faults=tuple(faults))

    def at(self, step: int, plane: Optional[str] = None) -> list:
        """The faults scheduled for ``step`` (optionally one plane)."""
        return [f for f in self.faults if f.step == step
                and (plane is None or f.plane == plane)]

    def text(self) -> str:
        """The CLI form (inverse of `parse`)."""
        return ",".join(f.text() for f in self.faults)

    def __bool__(self):
        return bool(self.faults)


# ---------------------------------------------------------------------------
# corruption patterns (the post-decode effect of each fault kind)
# ---------------------------------------------------------------------------

def _is_float(x) -> bool:
    """True for float/complex dtypes INCLUDING the ml_dtypes extended
    floats (bf16/f8 — numpy kind 'V', so a kind check misses them)."""
    try:
        return bool(jnp.issubdtype(x.dtype, jnp.floating)
                    or jnp.issubdtype(x.dtype, jnp.complexfloating))
    except (AttributeError, TypeError):
        return False


def corrupt_array(x, kind: str):
    """The ``kind``-corrupted version of a float array (int/bool
    arrays return unchanged — codes corruption is modeled post-decode
    on the float payload).  Deterministic, shape/dtype-preserving."""
    if not _is_float(x):
        return x
    if kind == "corrupt-codes":
        sign = (jnp.arange(x.size) % 2 * (-2) + 1).reshape(x.shape)
        return (sign * 1e32).astype(x.dtype)
    if kind == "nan-scale":
        return jnp.full_like(x, jnp.nan)
    if kind == "drop-hop":
        return jnp.zeros_like(x)
    raise ValueError(f"unknown fault kind {kind!r}")


def corrupt_tree(tree, kind: str):
    """`corrupt_array` over every float leaf of a pytree."""
    return jax.tree_util.tree_map(lambda l: corrupt_array(l, kind),
                                  tree)


# ---------------------------------------------------------------------------
# DP plane: internal wrapper wires (the registry pattern itself)
# ---------------------------------------------------------------------------

def fault_wire(base: str, kind: str) -> str:
    """Ensure the internal DP wrapper wire ``<base>+fault-<kind>`` is
    registered and return its name.  The wrapper delegates to the base
    wire's collective / simulator and corrupts the DECODED MEAN on the
    way out (the EF carry passes through — the guard poisons it).  It
    copies the base spec's flags (sharded/chunkable/psum_lowered/byte
    model) so `CommConfig` validation and chunk checks still hold, and
    registers ``internal=True`` so enumeration (CLI choices,
    ``--list-wires``, registry-completeness gates) never sees it.

    Swapping this name into ``comm.dp.wire`` for ONE step is the whole
    injection mechanism: trainer configs hash the wire name, so the
    fault step gets its own jit executable and clean steps keep the
    original — injection cannot perturb clean-step bits."""
    if kind not in FAULT_KINDS:
        raise ValueError(f"unknown fault kind {kind!r}")
    name = f"{base}+fault-{kind}"
    try:
        W.get_wire(name)
        return name
    except ValueError:
        pass
    spec = W.get_wire(base)

    def collective(v_grad, err, axis_name, bits, key, **kw):
        mean, new_err = spec.collective(v_grad, err, axis_name, bits,
                                        key, **kw)
        return corrupt_tree(mean, kind), new_err

    def sim_allreduce(grads_list, error_state, bits, key, **kw):
        out, new_err = spec.sim_allreduce(grads_list, error_state,
                                          bits, key, **kw)
        return corrupt_tree(out, kind), new_err

    W.register_wire(
        name, plane="dp-grad", internal=True,
        summary=f"FAULT-INJECTION wrapper: {base} with {kind} "
                f"corruption on the decoded mean (harness-only)",
        wire_bytes=spec.wire_bytes, collective=collective,
        sim_allreduce=sim_allreduce, sharded=spec.sharded,
        chunkable=spec.chunkable, psum_lowered=spec.psum_lowered)
    return name


def faulted_comm(comm, spec: FaultSpec):
    """``comm`` with the DP wire swapped for its fault wrapper (only
    meaningful for ``spec.plane == 'dp'``; other planes inject via
    `inject_sim_state` / the batcher)."""
    assert spec.plane == "dp", spec
    if not comm.dp.bits:
        raise ValueError("a dp fault needs dp.bits > 0 (the DP plane "
                         "is off)")
    return comm.with_(dp=comm.dp.with_(
        wire=fault_wire(comm.dp.wire, spec.kind)))


# ---------------------------------------------------------------------------
# fw / bw / zbuf planes: host-state injection between steps
# ---------------------------------------------------------------------------

def inject_sim_state(state: dict, spec: FaultSpec, comm) -> dict:
    """Corrupt the carried train state with the post-decode effect of
    ``spec``:

    * fw / zbuf (runner applies BEFORE the fault step): the stored
      message payload of boundary 0 (``m`` for raw buffers, ``scale``
      for z-bit quantized ones); ``drop-hop`` zeroes the payload
      while leaving ``seen`` rows marked, which is exactly the
      all-zero-seen-row sentinel the guard checks;
    * bw (runner applies AFTER the fault step, matching the real
      timing — a corrupt backward hop lands in the parameters at the
      update, after the forward already wrote clean messages): the
      first float leaf of ``params``;
    * dp: handled by `faulted_comm` (wire swap), not here.
    """
    if spec.plane == "dp":
        raise ValueError("dp faults inject via faulted_comm (wire "
                         "swap), not state corruption")
    state = dict(state)
    if spec.plane in ("fw", "zbuf"):
        bufs = dict(state["buffers"])
        payload = "m" if "m" in bufs else "scale"
        arrs = list(bufs[payload])
        if spec.kind == "drop-hop" and "codes" in bufs:
            codes = list(bufs["codes"])
            codes[0] = jnp.zeros_like(codes[0])
            bufs["codes"] = _restack(bufs["codes"], codes)
        arrs[0] = corrupt_array(arrs[0], spec.kind)
        bufs[payload] = _restack(bufs[payload], arrs)
        state["buffers"] = bufs
    elif spec.plane == "bw":
        leaves, treedef = jax.tree_util.tree_flatten(state["params"])
        for i, leaf in enumerate(leaves):
            if _is_float(leaf):
                leaves[i] = corrupt_array(leaf, spec.kind)
                break
        state["params"] = jax.tree_util.tree_unflatten(treedef, leaves)
    else:
        raise ValueError(f"plane {spec.plane!r} does not inject into "
                         f"train state")
    return state


def _restack(original, arrs: list):
    """Rebuild the boundary-stacked container ``original`` (an array
    stacked on axis 0, or a list/tuple of per-boundary arrays) from
    the edited per-boundary list."""
    if isinstance(original, (list, tuple)):
        return type(original)(arrs)
    return jnp.stack(arrs)


# ---------------------------------------------------------------------------
# in-graph guard (XLA cannot raise: poison to NaN, host raises later)
# ---------------------------------------------------------------------------

def guard_dp_pair(grads, new_err, *, expect_nonzero: bool = True):
    """In-graph guard on the decoded DP mean: if any float leaf of
    ``grads`` is non-finite or ``> GUARD_MAX``, or (with
    ``expect_nonzero``, the default) the WHOLE tree is all-zero (a
    dropped hop — a legitimate full gradient mean is never identically
    zero), NaN-poison both ``grads`` and the EF carry ``new_err`` so
    the host-side `check_train_state` attributes the fault to the dp
    plane.  ``expect_nonzero=False`` is for per-device SEGMENTS of the
    ZeRO wire, where a small model can leave one rank's segment
    entirely padding rows — legitimately zero.  On clean payloads the
    ``where`` selects the input elementwise — bit-exact, no effect on
    parity gates."""
    leaves = [l for l in jax.tree_util.tree_leaves(grads)
              if _is_float(l)]
    bad = jnp.zeros((), bool)
    if expect_nonzero:
        zero = jnp.ones((), bool)
        for l in leaves:
            zero &= jnp.all(l == 0)
        bad |= zero
    for l in leaves:
        bad |= jnp.any(~jnp.isfinite(l) | (jnp.abs(l) > GUARD_MAX))

    def poison(l):
        if not _is_float(l):
            return l
        return jnp.where(bad, jnp.asarray(jnp.nan, l.dtype), l)

    return (jax.tree_util.tree_map(poison, grads),
            jax.tree_util.tree_map(poison, new_err))


# ---------------------------------------------------------------------------
# host-side guards: scan state, raise structured errors
# ---------------------------------------------------------------------------

def _arr_detail(a) -> Optional[str]:
    if not _is_float(a):
        return None
    a = np.asarray(a)
    if a.dtype.kind not in "fc":
        a = a.astype(np.float32)       # ml_dtypes bf16/f8 (kind 'V')
    if not a.size:
        return None
    if not np.isfinite(a).all():
        return "non-finite values"
    if np.abs(a).max() > GUARD_MAX:
        return f"magnitude above guard bound {GUARD_MAX:g}"
    return None


def _tree_detail(tree) -> Optional[str]:
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        d = _arr_detail(leaf)
        if d:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path) or "<root>"
            return f"{key}: {d}"
    return None


def _buffers_detail(bufs) -> Optional[str]:
    """Corruption in the AQ-SGD message buffers: bad float payloads,
    or the drop-hop sentinel — a SEEN sample whose entire stored
    message is zero (a real message is a full-precision activation
    plus deltas; identically zero means the hop was dropped)."""
    payload = "m" if "m" in bufs else ("scale" if "scale" in bufs
                                      else None)
    if payload is None:
        return None
    d = _tree_detail({k: v for k, v in bufs.items() if k != "seen"})
    if d:
        return d
    seen = np.asarray(bufs["seen"])
    for i in range(seen.shape[0]):
        rows = np.flatnonzero(seen[i])
        if not rows.size:
            continue
        m = np.asarray(bufs[payload][i])[rows]
        zero = ~np.any(m.reshape(m.shape[0], -1) != 0, axis=1)
        if zero.any():
            return (f"boundary {i}: {int(zero.sum())} seen sample(s) "
                    f"with an all-zero stored message (dropped hop)")
    return None


def check_train_state(state: dict, *, comm, step: int,
                      loss=None) -> None:
    """Raise :class:`WireFaultError` if the post-step train state (or
    the step loss) carries a corrupt payload; return None when clean.

    Attribution is by which state each plane can reach, checked in
    dependency order (module docstring).  The message buffers come
    FIRST: they are written from the forward pass, so a corrupt DP
    decode (which happens after) can never contaminate them — clean
    buffers + bad ``dp_error`` is unambiguously a dp fault, while bad
    buffers point at the fw codec (stored at zbuf width when
    ``zbuf.bits``).  params / opt / loss are reachable by everything
    upstream and are attributed to the widest-reach compressed
    plane."""
    if "buffers" in state and comm.mode == "aqsgd":
        d = _buffers_detail(state["buffers"])
        if d:
            plane = "zbuf" if comm.zbuf.bits else "fw"
            raise WireFaultError(
                plane=plane, wire=getattr(comm, plane).wire, step=step,
                detail=f"message buffers: {d}")
    if "dp_error" in state:
        d = _tree_detail(state["dp_error"])
        if d:
            raise WireFaultError(plane="dp", wire=comm.dp.wire,
                                 step=step, detail=f"dp_error {d}")
    blame = "bw" if comm.bw.bits else ("dp" if comm.dp.bits else "fw")
    for name in ("params", "opt"):
        if name in state:
            d = _tree_detail(state[name])
            if d:
                raise WireFaultError(
                    plane=blame, wire=getattr(comm, blame).wire,
                    step=step, detail=f"{name} {d}")
    if loss is not None:
        # host-side diagnostic print, never on the wire
        # repro-lint: disable=no-silent-dtype-upcast
        d = _arr_detail(np.asarray(loss, dtype=np.float64))
        if d:
            raise WireFaultError(plane=blame,
                                 wire=getattr(comm, blame).wire,
                                 step=step, detail=f"loss {d}")


def slot_flags(pool: dict) -> np.ndarray:
    """Per-slot corruption flags for the serving batcher's pool (slot
    dim = axis 1 of every stacked leaf; the ``pos`` vector is axis 0).
    A slot is flagged when ANY of its float payload is non-finite or
    above ``GUARD_MAX``.  The caller masks with its active set —
    inactive slots hold stale bytes by design."""
    num_slots = int(np.asarray(pool["pos"]).shape[0])
    flags = np.zeros(num_slots, bool)
    for path, leaf in jax.tree_util.tree_flatten_with_path(pool)[0]:
        if not _is_float(leaf):
            continue
        a = np.asarray(leaf)
        if a.dtype.kind not in "fc":
            a = a.astype(np.float32)   # ml_dtypes bf16 (kind 'V')
        if a.ndim < 2 or a.shape[1] != num_slots:
            continue
        bad = ~np.isfinite(a) | (np.abs(a) > GUARD_MAX)
        axes = tuple(i for i in range(a.ndim) if i != 1)
        flags |= bad.any(axis=axes)
    return flags
