"""`repro.comm` — the single API for every inter-machine byte.

Four pieces (see each submodule's docstring):

* `repro.comm.codec`  — `Codec`: one plane's quantize/pack codec
  bound to its (bits, stochastic, backend) knobs;
* `repro.comm.wires`  — the named `WireSpec` registry
  (`register_wire` / `get_wire` / `list_wires`) with the uniform
  ``wire_bytes()`` accounting every byte report sources;
* `repro.comm.config` — `CommConfig`: per-plane sub-configs for the
  fw-activation / bw-gradient / z-buffer / dp-grad planes, with JSON
  and flat-CLI serialization;
* `repro.comm.faults` — deterministic fault injection (`FaultPlan`,
  internal wrapper wires) and the payload guards
  (`check_train_state`, `WireFaultError`) the recovery loop and the
  serving batcher consume.

`training/pipeline.py`, `training/simulated.py` and `launch/train.py`
consume this package; new wires land as registry entries, not trainer
surgery (the ``fp16`` DP passthrough is the in-tree example, and the
fault wrappers reuse the same mechanism as internal wires).
"""
from repro.comm.codec import Codec
from repro.comm.config import (CommConfig, PlaneConfig, add_cli_args,
                               from_args)
from repro.comm.faults import (FaultPlan, FaultSpec, WireFaultError,
                               check_train_state, fault_wire,
                               faulted_comm)
from repro.comm.wires import (PLANES, WireSpec, get_wire, list_wires,
                              register_wire, wire_names)

__all__ = [
    "Codec", "CommConfig", "FaultPlan", "FaultSpec", "PlaneConfig",
    "PLANES", "WireFaultError", "WireSpec", "add_cli_args",
    "check_train_state", "fault_wire", "faulted_comm", "from_args",
    "get_wire", "list_wires", "register_wire", "wire_names",
]
