"""`repro.comm` — the single API for every inter-machine byte.

Three pieces (see each submodule's docstring):

* `repro.comm.codec`  — `Codec`: one plane's quantize/pack codec
  bound to its (bits, stochastic, backend) knobs;
* `repro.comm.wires`  — the named `WireSpec` registry
  (`register_wire` / `get_wire` / `list_wires`) with the uniform
  ``wire_bytes()`` accounting every byte report sources;
* `repro.comm.config` — `CommConfig`: per-plane sub-configs for the
  fw-activation / bw-gradient / z-buffer / dp-grad planes, with JSON
  and flat-CLI serialization.

`training/pipeline.py`, `training/simulated.py` and `launch/train.py`
consume this package; new wires land as registry entries, not trainer
surgery (the ``fp16`` DP passthrough is the in-tree example).
"""
from repro.comm.codec import Codec
from repro.comm.config import (CommConfig, PlaneConfig, add_cli_args,
                               from_args)
from repro.comm.wires import (PLANES, WireSpec, get_wire, list_wires,
                              register_wire, wire_names)

__all__ = [
    "Codec", "CommConfig", "PlaneConfig", "PLANES", "WireSpec",
    "add_cli_args", "from_args", "get_wire", "list_wires",
    "register_wire", "wire_names",
]
