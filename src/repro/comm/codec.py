"""`Codec`: one plane's quantize/pack codec, bound to its knobs.

A :class:`Codec` is the (bits, stochastic, backend) triple of one
communication plane bound to the backend-selectable shared-scale
boundary ops of `repro.core.boundary` — encode/decode, the AQ-SGD
delta pair, the fake-quant roundtrip, and the error-feedback state
init of `repro.core.grad_compress`.  It adds nothing to the math: the
fused kernels and their bit-parity contract live below in
`core.boundary`; the codec only stops callers from re-threading
``bits=... stochastic=... backend=...`` through every call site.
`comm.config.PlaneConfig.codec()` is the usual constructor.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.core import boundary as B
from repro.core import grad_compress as GC
from repro.core import quantization as Q


@dataclass(frozen=True)
class Codec:
    """One plane's codec: knobs bound once, ops delegated to
    `core.boundary` (both backends bit-identical per op)."""
    bits: int
    stochastic: bool = True
    backend: str = "auto"

    def encode(self, x, *, key=None):
        """Quantize-and-pack: (packed u8 codes, f32 row scales)."""
        return B.encode(x, bits=self.bits, stochastic=self.stochastic,
                        key=key, backend=self.backend)

    def decode(self, packed, scale, *, d: int, dtype=jnp.float32):
        """Inverse of `encode`: payload + scales -> (..., d) values."""
        return B.decode(packed, scale, bits=self.bits, d=d, dtype=dtype,
                        backend=self.backend)

    def encode_delta(self, a, m, *, key=None):
        """AQ-SGD sender: (payload, scale, updated message buffer)."""
        return B.encode_delta(a, m, bits=self.bits,
                              stochastic=self.stochastic, key=key,
                              backend=self.backend)

    def decode_accumulate(self, packed, scale, m):
        """AQ-SGD receiver: buffer += dequant(unpack(payload))."""
        return B.decode_accumulate(packed, scale, m, bits=self.bits,
                                   backend=self.backend)

    def roundtrip(self, x, *, key=None):
        """encode -> decode in x.dtype (wire-faithful fake quant)."""
        return B.roundtrip(x, bits=self.bits, stochastic=self.stochastic,
                           key=key, backend=self.backend)

    def init_state(self, params, group_d: int = GC.DEFAULT_GROUP_D):
        """Error-feedback carry for one rank: the zeros
        (rows, group_d) bucket of `grad_compress.init_error_state`."""
        return GC.init_error_state(params, group_d)

    def wire_bytes(self, shape) -> int:
        """Payload bytes for one ``shape`` crossing: packed codes +
        f32 row scales (`Q.wire_bytes`)."""
        if not self.bits:
            import numpy as np
            return int(np.prod(shape)) * 4
        return Q.wire_bytes(shape, self.bits)
