"""`CommConfig`: one structured config for every inter-machine byte.

The paper's end-to-end story ("all communications between machines —
model gradients, forward activations, and backward gradients — are
compressed") plus the serving-side cache is five planes; this module
is their ONE configuration surface:

* ``fw``   — forward activations on the pipeline axis (AQ-SGD deltas
  or DirectQ codes on the ``ppermute`` wire; serving's decode hop
  rides the same plane — `serving.delta`);
* ``bw``   — backward activation gradients (DirectQ, reverse perm);
* ``zbuf`` — the z-bit stored message buffers (paper §H.5 — HBM
  residency, not network bytes);
* ``dp``   — model gradients on the data-parallel axes, carried by a
  named wire from the registry (`comm.wires`): ``ring`` / ``psum`` /
  ``ring-sharded`` / ``fp16`` / whatever a later PR registers;
* ``kv``   — the serving KV cache (`serving.kvcache`): b-bit packed
  codes + group scales in paged HBM slots, quantize-on-append /
  dequantize-on-attend.  ``group_d`` is the scale-group width along
  head_dim (0 = one scale per head row); like ``zbuf`` this is HBM
  residency, not network bytes.

Each plane is a :class:`PlaneConfig` (bits, stochastic, backend,
error-feedback, wire name, scale-group width); the whole thing
serializes to/from JSON (``to_json``/``from_json`` — the
``--comm-config`` CLI input) and to/from flat CLI flags
(``add_cli_args``/``from_args``/``to_flags`` — the
``--fw-bits ... --dp-wire ... --kv-bits`` surface), with round-trip
equality gated by tests/test_comm.py.  Wire names are validated
against the registry at construction, with a did-you-mean message.

`training/pipeline.py::PipelineConfig`, `training/simulated.py::
SimTrainConfig`, `launch/train.py` and `launch/serve.py` all consume
this.  The pre-registry scattered kwargs (``fw_bits``/``buffer_bits``/
``dp_grad_bits``/``dp_wire``/...) on the trainer configs are GONE:
passing one raises with a migration message (they spent their one
deprecation release warning).  `CommConfig.from_legacy` remains as
the explicit converter from a `CompressionConfig` + DP knobs.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass, field
from typing import Optional

from repro.comm import wires as W
from repro.comm.codec import Codec
from repro.core import grad_compress as GC
from repro.core.aqsgd import CompressionConfig

MODES = ("fp32", "directq", "aqsgd")
PLANE_FIELDS = ("fw", "bw", "zbuf", "dp", "kv")
# plane field name -> registry plane the wire name resolves against
PLANE_OF = {"fw": "fw-activation", "bw": "bw-gradient",
            "zbuf": "z-buffer", "dp": "dp-grad", "kv": "kv-cache"}
_DEFAULT_WIRE = {"fw": "ppermute", "bw": "ppermute", "zbuf": "hbm",
                 "dp": "ring", "kv": "paged"}


@dataclass(frozen=True)
class PlaneConfig:
    """Knobs of one communication plane.

    ``bits=0`` means uncompressed/off (raw dtype for the planes that
    have one; the DP plane is simply disabled).  ``wire`` is a name
    from the registry for the plane (empty = the plane's default).
    ``error_feedback`` is a DP-plane knob (``False`` drops the
    carried-error state: plain one-shot quantization); `CommConfig`
    normalizes it off on the other planes.  ``group_d`` is the DP
    bucket's scale-group width (0 = default).  ``chunks`` is the DP
    ring-family chunk count (K-chunk double-buffered schedule —
    bit- and byte-identical to the monolithic K=1); `CommConfig`
    validates it against the wire's ``chunkable`` registry flag and
    normalizes it to 1 on the other planes."""
    bits: int = 0
    stochastic: bool = True
    backend: str = "auto"
    error_feedback: bool = True
    wire: str = ""
    group_d: int = 0
    chunks: int = 1

    def codec(self) -> Codec:
        """The plane's `Codec` (bits/stochastic/backend bound once)."""
        return Codec(bits=self.bits, stochastic=self.stochastic,
                     backend=self.backend)

    def with_(self, **kw) -> "PlaneConfig":
        """`dataclasses.replace` shorthand."""
        return dataclasses.replace(self, **kw)


def _plane(**kw):
    return lambda: PlaneConfig(**kw)


@dataclass(frozen=True)
class CommConfig:
    """The five communication planes plus the activation algorithm.

    ``mode`` is the activation-boundary algorithm (``aqsgd`` /
    ``directq`` / ``fp32``) — it governs the fw plane and whether
    message buffers (and hence the zbuf plane) exist at all.
    ``buffer_dtype`` is the raw-storage dtype when ``zbuf.bits == 0``.
    ``kv`` is the serving cache plane: ``kv.bits=0`` keeps the raw
    cache dtype, ``kv.bits>0`` stores b-bit packed codes + f32 group
    scales (``kv.group_d`` = scale-group width along head_dim, 0 = one
    scale per head row); rounding defaults deterministic — a stored
    cache re-read many times should not be a noise source, but the
    knob exists for the error-analysis ablations.
    Construction validates modes, wire names (did-you-mean on typos),
    and fills empty wire names with each plane's default."""
    mode: str = "aqsgd"
    fw: PlaneConfig = field(default_factory=_plane(bits=4))
    bw: PlaneConfig = field(default_factory=_plane(bits=8))
    zbuf: PlaneConfig = field(default_factory=_plane(stochastic=False))
    dp: PlaneConfig = field(default_factory=_plane())
    kv: PlaneConfig = field(default_factory=_plane(stochastic=False))
    buffer_dtype: str = "float32"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r}; "
                             f"one of {MODES}")
        if self.mode != "fp32" and not self.fw.bits:
            raise ValueError(
                "fw.bits=0 (uncompressed forward) requires "
                "mode='fp32' — a compressed mode would silently fall "
                "back to a default width otherwise")
        for fname in PLANE_FIELDS:
            pc = getattr(self, fname)
            if not isinstance(pc, PlaneConfig):
                # dict (JSON) / legacy tuple tolerance: build a plane
                pc = PlaneConfig(**pc) if isinstance(pc, dict) else pc
            if not pc.wire:
                pc = pc.with_(wire=_DEFAULT_WIRE[fname])
            if fname == "dp" and not pc.group_d:
                pc = pc.with_(group_d=GC.DEFAULT_GROUP_D)
            spec = W.get_wire(pc.wire, plane=PLANE_OF[fname])
            if not isinstance(pc.chunks, int) \
                    or isinstance(pc.chunks, bool) or pc.chunks < 1:
                raise ValueError(
                    f"{fname}.chunks={pc.chunks!r} is invalid: the "
                    f"chunk count must be a positive int — did you "
                    f"mean chunks=1 (the monolithic schedule)?")
            if fname == "dp" and pc.chunks != 1 and not spec.chunkable:
                chunkable = [n for n in W.wire_names(PLANE_OF[fname])
                             if W.get_wire(n,
                                           plane=PLANE_OF[fname]
                                           ).chunkable]
                raise ValueError(
                    f"dp.chunks={pc.chunks} is not supported by wire "
                    f"{pc.wire!r} (not chunkable); chunkable wires: "
                    f"{', '.join(chunkable)} — did you mean "
                    f"wire={chunkable[0]!r}?")
            if fname != "dp" and pc.chunks != 1:
                # chunking is a DP ring-schedule knob; other planes
                # have no chunked collective to schedule
                pc = pc.with_(chunks=1)
            if fname != "dp" and pc.error_feedback:
                pc = pc.with_(error_feedback=False)
            if fname == "zbuf" and pc.stochastic:
                # buffer writes are deterministic by design: both
                # boundary replicas must store identical codes
                pc = pc.with_(stochastic=False)
            object.__setattr__(self, fname, pc)

    # -- derived views ----------------------------------------------------

    @property
    def activation(self) -> CompressionConfig:
        """The activation-plane view as the legacy `CompressionConfig`
        (what `core.aqsgd.apply_boundary` and the transfer builders
        consume).  The activation codec backend is the fw plane's.
        (fw.bits=0 only exists under mode='fp32' — validated at init —
        where the width is unused; the `or 4` keeps the legacy
        default there.)"""
        return CompressionConfig(
            mode=self.mode, fw_bits=self.fw.bits or 4,
            bw_bits=self.bw.bits or 32, buffer_bits=self.zbuf.bits,
            buffer_dtype=self.buffer_dtype,
            stochastic=self.fw.stochastic, backend=self.fw.backend)

    @property
    def dp_group_d(self) -> int:
        """The DP bucket scale-group width (normalized at init)."""
        return self.dp.group_d

    @property
    def dp_wire_spec(self) -> W.WireSpec:
        """The registry spec of the configured DP wire."""
        return W.get_wire(self.dp.wire, plane="dp-grad")

    def with_(self, **kw) -> "CommConfig":
        """`dataclasses.replace` shorthand."""
        return dataclasses.replace(self, **kw)

    # -- legacy bridge ----------------------------------------------------

    @classmethod
    def from_legacy(cls, cc: Optional[CompressionConfig] = None, *,
                    buffer_bits: Optional[int] = None,
                    dp_grad_bits: int = 0, dp_wire: str = "",
                    dp_grad_group: int = 0) -> "CommConfig":
        """Build from the pre-registry knob set: a `CompressionConfig`
        plus the scattered ``PipelineConfig``/``SimTrainConfig`` DP
        fields.  The explicit migration path now that those configs
        reject the old kwargs (`reject_legacy_comm`) — callers convert
        the knob set here and pass the result as ``comm=``."""
        cc = cc if cc is not None else CompressionConfig()
        zb = cc.buffer_bits if buffer_bits is None else buffer_bits
        return cls(
            mode=cc.mode,
            fw=PlaneConfig(bits=cc.fw_bits, stochastic=cc.stochastic,
                           backend=cc.backend),
            bw=PlaneConfig(bits=0 if cc.bw_bits >= 32 else cc.bw_bits,
                           stochastic=cc.stochastic, backend=cc.backend),
            zbuf=PlaneConfig(bits=zb, stochastic=False,
                             backend=cc.backend),
            dp=PlaneConfig(bits=dp_grad_bits, error_feedback=True,
                           wire=dp_wire, group_d=dp_grad_group,
                           backend=cc.backend,
                           stochastic=cc.stochastic),
            kv=PlaneConfig(stochastic=False, backend=cc.backend),
            buffer_dtype=cc.buffer_dtype)

    # -- JSON -------------------------------------------------------------

    def to_dict(self) -> dict:
        """Plain-dict form (all fields, stable keys)."""
        return {"mode": self.mode, "buffer_dtype": self.buffer_dtype,
                **{f: dataclasses.asdict(getattr(self, f))
                   for f in PLANE_FIELDS}}

    @classmethod
    def from_dict(cls, d: dict) -> "CommConfig":
        """Inverse of `to_dict`; unknown keys (top-level or per-plane)
        raise, so typos cannot silently no-op."""
        d = dict(d)
        kw = {}
        for top in ("mode", "buffer_dtype"):
            if top in d:
                kw[top] = d.pop(top)
        pfields = {f.name for f in dataclasses.fields(PlaneConfig)}
        for fname in PLANE_FIELDS:
            if fname not in d:
                continue
            sub = dict(d.pop(fname))
            unknown = set(sub) - pfields
            if unknown:
                raise ValueError(
                    f"unknown {fname} plane key(s) {sorted(unknown)}; "
                    f"known: {sorted(pfields)}")
            base = {f.name: getattr(_default_plane(fname), f.name)
                    for f in dataclasses.fields(PlaneConfig)}
            base.update(sub)
            kw[fname] = PlaneConfig(**base)
        if d:
            raise ValueError(f"unknown CommConfig key(s) {sorted(d)}; "
                             f"known: mode, buffer_dtype, "
                             f"{', '.join(PLANE_FIELDS)}")
        return cls(**kw)

    def to_json(self, **kw) -> str:
        """JSON form (the ``--comm-config`` input format)."""
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "CommConfig":
        """Parse `to_json` output (or any subset of its keys)."""
        return cls.from_dict(json.loads(s))

    # -- flat CLI flags ---------------------------------------------------

    def to_flags(self) -> list[str]:
        """The flat-flag form of this config (inverse of
        `from_args`).  Raises if the config uses per-plane settings the
        flat surface cannot express (differing backends or stochastic
        across planes, non-default fw/bw/zbuf wires) — use
        ``--comm-config`` JSON for those."""
        planes = [self.fw, self.bw, self.dp]
        if len({p.backend for p in planes + [self.zbuf, self.kv]}) > 1:
            raise ValueError("per-plane backends differ; flat flags "
                             "cannot express this — use --comm-config")
        if len({p.stochastic for p in planes}) > 1:
            raise ValueError("per-plane stochastic differs; use "
                             "--comm-config")
        if self.kv.stochastic:
            raise ValueError("kv.stochastic is not flag-expressible "
                             "(flat --kv-bits builds a deterministic "
                             "cache codec); use --comm-config")
        for fname in ("fw", "bw", "zbuf", "kv"):
            if getattr(self, fname).wire != _DEFAULT_WIRE[fname]:
                raise ValueError(f"non-default {fname} wire; use "
                                 "--comm-config")
            if getattr(self, fname).group_d:
                raise ValueError(f"{fname}.group_d is not "
                                 "flag-expressible; use --comm-config")
        if self.buffer_dtype != "float32":
            raise ValueError("non-default buffer_dtype; use "
                             "--comm-config")
        flags = ["--mode", self.mode,
                 "--fw-bits", str(self.fw.bits),
                 "--bw-bits", str(self.bw.bits),
                 "--buffer-bits", str(self.zbuf.bits),
                 "--dp-grad-bits", str(self.dp.bits),
                 "--dp-wire", self.dp.wire,
                 "--dp-grad-group", str(self.dp_group_d),
                 "--dp-chunks", str(self.dp.chunks),
                 "--kv-bits", str(self.kv.bits),
                 "--backend", self.fw.backend]
        if not self.fw.stochastic:
            flags.append("--no-stochastic")
        if not self.dp.error_feedback:
            flags.append("--no-error-feedback")
        return flags


def _default_plane(fname: str) -> PlaneConfig:
    return getattr(CommConfig(), fname)


def reject_legacy_comm(cls_name: str, legacy: dict) -> None:
    """The post-deprecation gate for configs whose scattered comm
    kwargs (``compression=``, ``dp_grad_bits=``, ``dp_wire=``, ...)
    have been removed in favor of ``comm=CommConfig(...)``.  The old
    names are kept as construction-only parameters SOLELY so that
    passing one raises THIS loud, actionable error instead of an
    opaque ``unexpected keyword argument``.  ``legacy`` maps kwarg
    name -> passed value (None = not passed)."""
    passed = sorted(k for k, v in legacy.items() if v is not None)
    if passed:
        raise TypeError(
            f"{cls_name}({', '.join(k + '=...' for k in passed)}) was "
            f"removed: the scattered comm kwargs spent their one "
            f"deprecation release and are now errors.  Pass "
            f"comm=CommConfig(...) (repro.comm) instead — "
            f"CommConfig.from_legacy(CompressionConfig(...), "
            f"dp_grad_bits=..., dp_wire=...) converts the old knob "
            f"set verbatim")


def add_cli_args(ap) -> None:
    """Install the flat comm flags plus ``--comm-config`` on an
    argparse parser.  The ``--dp-wire`` choices AND per-wire help
    one-liners come from the registry metadata, so the help text
    cannot drift from the registered wires."""
    dp_names = W.wire_names("dp-grad")
    dp_help = "; ".join(f"{n}: {W.get_wire(n).summary}"
                        for n in dp_names)
    ap.add_argument("--mode", default="aqsgd", choices=list(MODES),
                    help="activation-boundary algorithm (fw plane)")
    ap.add_argument("--fw-bits", type=int, default=4,
                    help="forward activation code width")
    ap.add_argument("--bw-bits", type=int, default=8,
                    help="backward activation-gradient code width "
                         "(0 = uncompressed)")
    ap.add_argument("--buffer-bits", type=int, default=0,
                    help="z-bit stored message buffers (0 = raw dtype)")
    ap.add_argument("--dp-grad-bits", type=int, default=0,
                    help="b-bit error-feedback gradient compression on "
                         "the DP axes (0 = off; Fig. 5 end-to-end mode)")
    ap.add_argument("--dp-wire", default="ring", choices=dp_names,
                    help="DP gradient collective — " + dp_help)
    ap.add_argument("--dp-grad-group", type=int,
                    default=GC.DEFAULT_GROUP_D,
                    help="DP gradient-bucket scale-group width")
    chunkable = [n for n in dp_names if W.get_wire(n).chunkable]
    ap.add_argument("--dp-chunks", type=int, default=1,
                    help="ring chunk count K: double-buffer the DP "
                         "collective (encode chunk k+1 while chunk "
                         "k's hops fly) — bit- and byte-identical to "
                         "the monolithic K=1; chunkable wires (from "
                         "the registry): " + ", ".join(chunkable))
    ap.add_argument("--kv-bits", type=int, default=0,
                    help="serving KV-cache code width (0 = raw cache "
                         "dtype; quantize-on-append, "
                         "dequantize-on-attend)")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "reference", "pallas"],
                    help="boundary codec backend for every plane")
    ap.add_argument("--no-stochastic", action="store_true",
                    help="deterministic rounding on every plane")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="drop the DP carried-error state (one-shot "
                         "quantization)")
    ap.add_argument("--comm-config", default="",
                    help="full CommConfig as JSON — a literal string "
                         "or a path to a .json file; overrides the "
                         "flat comm flags above")


def from_args(args) -> "CommConfig":
    """Build a `CommConfig` from parsed `add_cli_args` flags.
    ``--comm-config`` (JSON literal or file path) wins wholesale over
    the flat flags when given."""
    if getattr(args, "comm_config", ""):
        src = args.comm_config
        if os.path.exists(src):
            with open(src) as f:
                src = f.read()
        return CommConfig.from_json(src)
    stoch = not args.no_stochastic
    common = dict(stochastic=stoch, backend=args.backend)
    return CommConfig(
        mode=args.mode,
        fw=PlaneConfig(bits=args.fw_bits, **common),
        bw=PlaneConfig(bits=args.bw_bits, **common),
        zbuf=PlaneConfig(bits=args.buffer_bits, stochastic=False,
                         backend=args.backend),
        dp=PlaneConfig(bits=args.dp_grad_bits, wire=args.dp_wire,
                       group_d=args.dp_grad_group,
                       chunks=getattr(args, "dp_chunks", 1),
                       error_feedback=not args.no_error_feedback,
                       **common),
        kv=PlaneConfig(bits=getattr(args, "kv_bits", 0),
                       stochastic=False, backend=args.backend))
