"""Bit-faithful single-process simulation of AQ-SGD pipeline training.

Mathematically identical to the K-machine distributed algorithm
(Algorithm 2): the model trunk is cut into K stages; at each of the K-1
boundaries the activation is replaced by the message m(ξ) (full precision
on first visit, += Q(Δ) afterwards) and the backward activation gradient
is quantized — exactly what the wire carries.  Because the simulation and
the distributed runtime share `core.aqsgd.apply_boundary`, convergence
results measured here transfer to the shard_map pipeline bit-for-bit
(up to collective reduction order).

This is the engine behind the paper-validation benchmarks (Fig. 1a/3/5/9).

The boundary codec backend (fused Pallas kernels vs reference jnp chain)
is selected by ``CompressionConfig.backend`` and flows through
``apply_boundary``/``read_buffer``/``write_buffer`` unchanged.  The two
backends are bit-identical per op (see core.boundary), so convergence
results measured here transfer across backends up to the usual
compiler-fusion ulp noise in the surrounding model compute.

All communication knobs live in ``SimTrainConfig.comm``
(`repro.comm.CommConfig`; the pre-registry flat kwargs now raise with
a migration message), and the DP wire is simulated by its registered
`WireSpec.sim_allreduce` from the wire registry.

DP gradient compression (Fig. 5, ``comm.dp.bits > 0``) uses the bucketed
error-feedback codec of `core.grad_compress`: each simulated worker's
gradient tree is flattened into one (rows, group_d) bucket, quantized
against the cross-worker shared scale through the fused boundary codec,
and accumulated as int32 codes — the identical math the shard_map
pipeline's `core.collectives.ef_psum_mean_bucket` wire executes, so this
simulation is bit-faithful to the distributed gradient wire (int32 code
sums are exact in any reduction order).

``dp_sharded=True`` simulates the ZeRO-sharded wire end-to-end: the
allreduce stops at the reduce-scatter midpoint
(`grad_compress.compress_reduce_scatter` — worker i keeps only its
owned segment's mean), AdamW runs in bucket space on segment owners
(`optim.adamw.apply_bucket_updates`, moments one segment per worker),
and the updated parameter bucket is reassembled — the same loop
`training/pipeline.py` runs under ``dp_wire="ring-sharded"``, here on
genuinely DISTINCT per-worker gradients.  Losses are bit-identical to
the ``dp_sharded=False`` path while trajectories coincide and track at
ulp level after (cross-program XLA fusion noise, not codec
divergence) — pinned by tests/test_grad_compress.py.
"""
from __future__ import annotations

import functools
from dataclasses import InitVar, dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.comm import faults as faults_mod
from repro.comm.config import CommConfig, reject_legacy_comm
from repro.configs.base import ModelConfig
from repro.core import aqsgd
from repro.core import grad_compress
from repro.core.aqsgd import CompressionConfig
from repro.models import model as Mo
from repro.optim import adamw


@dataclass(frozen=True)
class SimTrainConfig:
    """Simulated-trainer knobs.  All communication lives in ``comm``
    (`repro.comm.CommConfig`); the DP plane's wire is simulated by its
    registered `WireSpec.sim_allreduce` (bit-faithful to the shard_map
    collective for the codec wires, math-faithful for passthroughs
    like ``fp16``).  The trailing init-only parameters are the REMOVED
    pre-registry kwargs (``compression=...``, ``dp_grad_bits=...``,
    ``dp_grad_group=...``, ``dp_sharded=...``) — kept only so passing
    one raises a loud migration error pointing at ``comm=``.  Read the
    old values off ``comm`` directly (``cfg.comm.dp.bits``,
    ``cfg.comm.activation``, ``cfg.comm.dp_wire_spec.sharded``, ...)."""
    num_stages: int = 4
    comm: Optional[CommConfig] = None
    optimizer: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)
    dp_workers: int = 1             # simulated DP degree when dp bits > 0
    remat: bool = False
    # ---- REMOVED kwargs: raise with a migration message -----------------
    compression: InitVar[Optional[CompressionConfig]] = None
    dp_grad_bits: InitVar[Optional[int]] = None
    dp_grad_group: InitVar[Optional[int]] = None
    dp_sharded: InitVar[Optional[bool]] = None

    def __post_init__(self, compression, dp_grad_bits, dp_grad_group,
                      dp_sharded):
        reject_legacy_comm(
            "SimTrainConfig",
            {"compression": compression, "dp_grad_bits": dp_grad_bits,
             "dp_grad_group": dp_grad_group, "dp_sharded": dp_sharded})
        if self.comm is None:
            object.__setattr__(self, "comm", CommConfig())

    def with_comm(self, comm: CommConfig) -> "SimTrainConfig":
        """Copy with ``comm`` swapped (equivalent to
        ``dataclasses.replace``; kept because it predates the removal
        of the legacy mirror kwargs)."""
        import dataclasses as _dc
        return _dc.replace(self, comm=comm)


def init_train_state(mcfg: ModelConfig, tcfg: SimTrainConfig,
                     num_samples: int, seq_len: int, key) -> dict:
    params = Mo.init_params(mcfg, key)
    dpc = tcfg.comm.dp
    if dpc.bits and tcfg.comm.dp_wire_spec.sharded:
        # ZeRO sim: segment-partitioned bucket moments, one per worker
        lay = grad_compress.bucket_layout(params, dpc.group_d)
        seg = grad_compress.ring_segment_rows(lay.rows,
                                              tcfg.dp_workers)
        opt = adamw.init_bucket_opt_state(tcfg.dp_workers, seg,
                                          lay.group_d)
    else:
        opt = adamw.init_opt_state(params)
    state = {
        "params": params,
        "opt": opt,
        "buffers": aqsgd.init_buffers(
            tcfg.comm.activation, tcfg.num_stages - 1, num_samples,
            seq_len, mcfg.d_model),
    }
    if dpc.bits:
        err = grad_compress.init_error_state(params, dpc.group_d)
        state["dp_error"] = jnp.stack([err] * tcfg.dp_workers)
    return state


def _loss_with_boundaries(params, mcfg, tcfg, batch, m_all, seen_all, key):
    cc = tcfg.comm.activation
    nb = tcfg.num_stages - 1

    def boundary_fn(bstate, h, idx):
        kb = jax.random.fold_in(key, idx)
        m = m_all[idx] if m_all is not None else None
        seen = seen_all[idx] if seen_all is not None else None
        h2, m_new = aqsgd.apply_boundary(cc, h, kb, m, seen)
        return bstate + (m_new,), h2

    loss, metrics = Mo.loss_fn(
        params, mcfg, batch, num_stages=tcfg.num_stages,
        boundary_fn=boundary_fn, boundary_state=(), remat=tcfg.remat)
    return loss, metrics


@functools.partial(jax.jit, static_argnames=("mcfg", "tcfg"))
def train_step(state, batch, key, *, mcfg: ModelConfig,
               tcfg: SimTrainConfig):
    """One AQ-SGD training step.  batch must include sample_ids."""
    cc = tcfg.comm.activation
    dpc = tcfg.comm.dp
    dp_spec = tcfg.comm.dp_wire_spec if dpc.bits else None
    dp_sharded = bool(dp_spec is not None and dp_spec.sharded)
    bufs = state["buffers"]
    ids = batch["sample_ids"]
    if cc.mode == "aqsgd":
        m_all = [aqsgd.read_buffer(cc, bufs, i, ids, mcfg.d_model)
                 for i in range(tcfg.num_stages - 1)]
        seen_all = [bufs["seen"][i][ids] for i in range(tcfg.num_stages - 1)]
    else:
        m_all = seen_all = None

    grad_fn = jax.value_and_grad(
        lambda p: _loss_with_boundaries(p, mcfg, tcfg, batch, m_all,
                                        seen_all, key), has_aux=True)

    if dpc.bits and (tcfg.dp_workers > 1 or dp_sharded):
        # Fig. 5 mode: split the batch over simulated DP workers, then
        # run the configured wire's registered simulator
        # (`WireSpec.sim_allreduce`) over the per-worker gradient trees
        # — bit-faithful to the shard_map collective for the codec
        # wires (psum/ring/ring-sharded), math-faithful for
        # passthroughs like fp16 (f16 sums are order-dependent).
        w = tcfg.dp_workers
        b = batch["tokens"].shape[0] // w
        glist, loss = [], 0.0
        new_ms_parts, ce = [], 0.0
        for i in range(w):
            sub = {k: v[i * b:(i + 1) * b] for k, v in batch.items()}
            sub_m = [m[:, i * b:(i + 1) * b] if m.ndim > 3 else
                     m[i * b:(i + 1) * b] for m in m_all] if m_all else None
            sub_s = [s[i * b:(i + 1) * b] for s in seen_all] \
                if seen_all else None
            (l, met), g = jax.value_and_grad(
                lambda p: _loss_with_boundaries(
                    p, mcfg, tcfg, sub, sub_m, sub_s,
                    jax.random.fold_in(key, 1000 + i)), has_aux=True)(
                        state["params"])
            glist.append(g)
            loss = loss + l / w
            ce = ce + met["ce"] / w
            new_ms_parts.append(met["boundary_state"])
        glay = grad_compress.bucket_layout(glist[0], dpc.group_d)
        # sharded wires stop at the reduce-scatter midpoint — worker i
        # keeps only its owned segment's mean; the bucket-space
        # optimizer below updates owned segments and reassembles.
        err_in = state["dp_error"] if dpc.error_feedback \
            else jnp.zeros_like(state["dp_error"])
        grads, new_err = dp_spec.sim_allreduce(
            glist, err_in, dpc.bits,
            jax.random.fold_in(key, 2000), stochastic=dpc.stochastic,
            backend=dpc.backend, layout=glay)
        # payload guard: NaN-poison a corrupt decoded mean (and the EF
        # carry, so the fault is attributable to the dp plane); clean
        # payloads pass through bit-exactly
        grads, new_err = faults_mod.guard_dp_pair(grads, new_err)
        if not dpc.error_feedback:
            new_err = jnp.zeros_like(new_err)
        new_state_extra = {"dp_error": new_err}
        if cc.mode == "aqsgd":
            # workers own disjoint batch shards; concat their new messages
            nb = tcfg.num_stages - 1
            bstate = tuple(
                jnp.concatenate([new_ms_parts[i][j] for i in range(w)],
                                axis=0) for j in range(nb))
        else:
            bstate = ()
        metrics = {"ce": ce, "aux": 0.0, "boundary_state": bstate}
    elif dpc.bits:
        # single-worker error feedback: the n=1 wire through the same
        # registered simulator (bit-identical to the old
        # `compress_gradients` path for the codec wires: the n=1 code
        # sum decodes through the identical `decode_sum_mean`).
        (loss, metrics), grads = grad_fn(state["params"])
        err_in = state["dp_error"] if dpc.error_feedback \
            else jnp.zeros_like(state["dp_error"])
        grads, new_err = dp_spec.sim_allreduce(
            [grads], err_in, dpc.bits,
            jax.random.fold_in(key, 2000), stochastic=dpc.stochastic,
            backend=dpc.backend,
            layout=grad_compress.bucket_layout(grads, dpc.group_d))
        grads, new_err = faults_mod.guard_dp_pair(grads, new_err)
        if not dpc.error_feedback:
            new_err = jnp.zeros_like(new_err)
        new_state_extra = {"dp_error": new_err}
    else:
        (loss, metrics), grads = grad_fn(state["params"])
        new_state_extra = {}

    if dpc.bits and dp_sharded:
        # segment-owner update in bucket space + parameter reassembly
        # (the sim analogue of the pipeline's parameter all-gather):
        # bit-identical losses to the allreduce + per-leaf AdamW path
        w = tcfg.dp_workers
        lay = grad_compress.bucket_layout(state["params"], dpc.group_d)
        seg = grad_compress.ring_segment_rows(lay.rows, w)
        pb = grad_compress.flatten_bucket(state["params"], lay)
        pad = seg * w - lay.rows
        if pad:
            pb = jnp.pad(pb, ((0, pad), (0, 0)))
        new_pb, opt = adamw.apply_bucket_updates(
            tcfg.optimizer, pb.reshape(w, seg, lay.group_d), grads,
            state["opt"])
        params = grad_compress.unflatten_bucket(
            new_pb.reshape(w * seg, lay.group_d)[:lay.rows], lay,
            state["params"])
    else:
        params, opt = adamw.apply_updates(
            tcfg.optimizer, state["params"], grads, state["opt"])

    if cc.mode == "aqsgd":
        new_ms = metrics.pop("boundary_state")
        for i, m_new in enumerate(new_ms):
            bufs = aqsgd.write_buffer(cc, bufs, i, ids, m_new)
    else:
        metrics.pop("boundary_state", None)

    new_state = {"params": params, "opt": opt, "buffers": bufs,
                 **new_state_extra}
    metrics = {"loss": loss, "ce": metrics["ce"], "aux": metrics["aux"]}
    return new_state, metrics


def train(mcfg: ModelConfig, tcfg: SimTrainConfig, dataset, *,
          num_steps: int, batch_size: int, key=None, log_every: int = 0,
          initial_params=None):
    """Run the simulated trainer; returns (state, list of per-step loss).

    initial_params: start from a pre-trained checkpoint (the paper's
    fine-tuning setting) instead of random init."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k_init, k_run = jax.random.split(key)
    state = init_train_state(mcfg, tcfg, dataset.num_samples,
                             dataset.dc.seq_len, k_init)
    if initial_params is not None:
        state["params"] = initial_params
    losses = []
    for step, batch in enumerate(dataset.batches(batch_size, num_steps)):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = train_step(state, batch,
                                    jax.random.fold_in(k_run, step),
                                    mcfg=mcfg, tcfg=tcfg)
        losses.append(float(metrics["loss"]))
        if log_every and step % log_every == 0:
            print(f"step {step:5d} loss {losses[-1]:.4f}")
    return state, losses
