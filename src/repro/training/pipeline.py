"""Distributed pipeline-parallel training with AQ-SGD boundary compression.

Mesh: ``(data=D, model=K)`` (+ leading ``pod`` for multi-pod).  The
``model`` axis carries the K pipeline stages — the paper's setting (its
experiments cut the model onto 8 machines; the production mesh uses 16).
The ``data``/``pod`` axes carry data parallelism with per-layer ZeRO-3
weight gathering (stage weights of e.g. mixtral-8x22b do not fit one chip).

Schedule: GPipe with M microbatches as a ``lax.scan`` over T = M + K - 1
ticks inside ``shard_map``.  Each tick every stage computes its current
microbatch and ships the boundary activation to the next stage with
``ppermute``.  Autodiff of the scan yields the reverse (backward)
pipeline automatically; the boundary transfer is a ``custom_vjp`` so that

* forward wire  = packed uint8 delta codes + per-row scales (AQ-SGD), and
* backward wire = packed uint8 gradient codes + scales (bw-bit DirectQ),

i.e. the lowered ``collective-permute`` ops genuinely carry 2-8 bit
payloads — the compression shows up in the §Roofline collective term.

All communication knobs live in ``PipelineConfig.comm``
(`repro.comm.CommConfig`: fw / bw / z-buffer / dp planes; the old flat
kwargs now raise with a migration message), and the DP collective is resolved
by name from the wire registry (`repro.comm.wires`), so a newly
registered wire reaches this trainer with no changes here.

DP gradient wire (``comm.dp.bits > 0``, paper Fig. 5 "end-to-end
communication compression"): the whole gradient tree is flattened into
one bucketed (rows, group_d) array and allreduced over the DP axes —
pmax-shared rowwise scales, fused codes-only quantize, exact int32 code
accumulation, fused dequant-mean — with per-rank error-feedback state
(``dp_error`` in the train state, sharded one bucket per DP rank).
``comm.dp.wire`` picks the collective: the bandwidth-optimal compressed ring
(packed b-bit codes on ``ppermute`` hops, local unpack-accumulate —
the default), the conservative i32-lane code ``psum``, or the
ZeRO-sharded ``ring-sharded`` (the ring stopped at its reduce-scatter
midpoint: each rank keeps only its owned segment's mean, AdamW runs in
bucket space on segment owners — `adamw.apply_bucket_updates` with
moments partitioned one segment per rank — and the f32 UPDATED
parameter segments all-gather explicitly inside
`make_dp_sharded_update`, the gather ZeRO trades for the gradient
all-gather); all three produce
bit-identical gradient values (see `make_dp_grad_wire` /
`make_dp_sharded_update`).  The wire FUNCTIONS are
bit-identical to the simulator's `grad_compress.compress_allreduce` /
`compress_reduce_scatter` (tests/workers/dp_grad_worker.py feeds them
DISTINCT per-rank buckets — the local-gradient regime — and compares
bit-for-bit, so the wires, the error-feedback layout, and the sharded
optimizer state are all proven on per-rank partial gradients; the
simulator's ``dp_sharded`` mode runs that full ZeRO loop on genuinely
distinct per-worker gradients).  Placement caveat: in THIS train step
the bucket each rank feeds in is the gradient `jax.value_and_grad`
already produced at the pjit level — which includes XLA's fp32
cross-data reduction — so the collective performs n independent
stochastic quantizations of the shared gradient with per-rank error
feedback (the pure-DP / pod-axis semantics).  That placement is what
keeps all three wires loss-identical end-to-end; feeding the pipeline
wire from pre-reduction local cotangents (a custom_vjp on
`gather_fsdp` / a shard_map'd per-rank loss) remains a ROADMAP item.

Message buffers: each device holds ``m_out`` (its outgoing boundary) and
``m_in`` (a replica of the upstream stage's buffer).  Both sides apply
the *same* quantized delta so they stay bit-identical (Algorithm 2).  The
first epoch runs the ``warmup=True`` step variant: uncompressed transfer
that initializes the buffers (the paper's warm-up epoch).

Stage homogeneity: layer stacks are zero-padded to K*lps and dead layers
are skipped with ``lax.cond`` (counted in §Roofline's useful-FLOPs
ratio); zamba2's shared attention block is invoked by per-layer flag,
also under ``lax.cond``.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import InitVar, dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm import faults as CF
from repro.comm import wires as CW
from repro.comm.config import CommConfig, reject_legacy_comm
from repro.configs.base import ModelConfig
from repro.core import boundary as B
from repro.core import collectives as C
from repro.core import grad_compress as GC
from repro.core import quantization as Q
from repro.core.aqsgd import CompressionConfig
from repro.launch.mesh import data_axes, shard_map
from repro.models import layers as L
from repro.models import model as Mo
from repro.models import moe as Me
from repro.models import ssm as S
from repro.optim import adamw


@dataclass(frozen=True)
class PipelineConfig:
    """Pipeline-trainer knobs.  All communication lives in ``comm``
    (`repro.comm.CommConfig`: fw / bw / z-buffer / dp planes, wire
    names from the registry); ``comm=None`` means the default
    `CommConfig()`.  The trailing init-only parameters are the REMOVED
    pre-registry kwargs (``compression=...``, ``buffer_bits=...``,
    ``dp_grad_bits=...``, ``dp_grad_group=...``, ``dp_wire=...``) —
    kept only so passing one raises a loud migration error pointing at
    ``comm=`` instead of an opaque TypeError.  Read the old values off
    ``comm`` directly (``cfg.comm.dp.bits``, ``cfg.comm.activation``,
    ...); ``dataclasses.replace(cfg, comm=new)`` and ``with_comm``
    both swap comm."""
    microbatches: int = 16
    comm: Optional[CommConfig] = None
    warmup: bool = False            # warm-up epoch: uncompressed, fills m
    remat: bool = True
    block_k: int = 512
    buffer_dtype: str = "bfloat16"  # HBM-resident message buffer precision
    loss_chunks: int = 64           # sequential CE chunks (bounds logits mem)
    moe_mode: str = "zero3"         # zero3 | expert_parallel (§Perf)
    remat_mode: str = "nested"      # nested | layer (§Perf: nested saves
                                    # HBM, layer saves one fwd recompute)
    # ---- REMOVED kwargs: raise with a migration message -----------------
    compression: InitVar[Optional[CompressionConfig]] = None
    buffer_bits: InitVar[Optional[int]] = None
    dp_grad_bits: InitVar[Optional[int]] = None
    dp_grad_group: InitVar[Optional[int]] = None
    dp_wire: InitVar[Optional[str]] = None

    def __post_init__(self, compression, buffer_bits, dp_grad_bits,
                      dp_grad_group, dp_wire):
        reject_legacy_comm(
            "PipelineConfig",
            {"compression": compression, "buffer_bits": buffer_bits,
             "dp_grad_bits": dp_grad_bits,
             "dp_grad_group": dp_grad_group, "dp_wire": dp_wire})
        if self.comm is None:
            object.__setattr__(self, "comm", CommConfig())

    def with_comm(self, comm: CommConfig) -> "PipelineConfig":
        """Copy of this config with ``comm`` swapped (equivalent to
        ``dataclasses.replace(self, comm=comm)``; kept because it
        predates the removal of the legacy mirror kwargs)."""
        return dataclasses.replace(self, comm=comm)


# ---------------------------------------------------------------------------
# stage layout: pad layers to K * lps, per-layer flags
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StageLayout:
    num_stages: int
    lps: int                         # layers per stage (padded)
    n_layers: int                    # live layers in the pipeline trunk
    n_padded: int
    shared_attn: bool                # zamba2


def stage_layout(cfg: ModelConfig, num_stages: int) -> StageLayout:
    n = cfg.num_layers - cfg.first_dense_layers
    lps = -(-n // num_stages)
    return StageLayout(num_stages, lps, n, num_stages * lps - n,
                       cfg.family == "hybrid")


def pad_stack(tree, n_pad: int):
    if n_pad == 0:
        return tree
    return jax.tree.map(
        lambda a: jnp.pad(a, [(0, n_pad)] + [(0, 0)] * (a.ndim - 1)), tree)


def to_pipeline_params(cfg: ModelConfig, params, num_stages: int):
    """Canonical init_params -> pipeline layout (stage-stacked trunk)."""
    lay = stage_layout(cfg, num_stages)
    p = dict(params)
    trunk = pad_stack(p.pop("layers"), lay.n_padded)
    p["stages"] = jax.tree.map(
        lambda a: a.reshape(num_stages, lay.lps, *a.shape[1:]), trunk)
    return p


def from_pipeline_params(cfg: ModelConfig, params, num_stages: int):
    lay = stage_layout(cfg, num_stages)
    p = dict(params)
    stages = p.pop("stages")
    trunk = jax.tree.map(
        lambda a: a.reshape(num_stages * lay.lps, *a.shape[2:])[:lay.n_layers],
        stages)
    p["layers"] = trunk
    return p


def layer_flags(cfg: ModelConfig, lay: StageLayout, seq_len: int):
    """Per padded-layer vectors: window, live mask, shared-attn flag."""
    n, total = lay.n_layers, lay.num_stages * lay.lps
    off = cfg.first_dense_layers
    windows = np.array(
        [cfg.layer_window(i + off, seq_len) for i in range(n)]
        + [seq_len] * lay.n_padded, np.int32)
    live = np.array([True] * n + [False] * lay.n_padded)
    shared = np.array(
        [cfg.layer_has_shared_attn(i) for i in range(n)]
        + [False] * lay.n_padded)
    return (jnp.asarray(windows).reshape(lay.num_stages, lay.lps),
            jnp.asarray(live).reshape(lay.num_stages, lay.lps),
            jnp.asarray(shared).reshape(lay.num_stages, lay.lps))


# ---------------------------------------------------------------------------
# FSDP (ZeRO-3) sharding of stage-stacked params over the data axis
# ---------------------------------------------------------------------------

def fsdp_dim(shape, dsize: int, skip: int) -> Optional[int]:
    """Dim (>= skip) to shard over data: first trailing dim divisible."""
    for i in range(skip, len(shape)):
        if shape[i] % dsize == 0 and shape[i] >= dsize:
            return i
    return None


def pipeline_param_specs(mesh, params_shape) -> Any:
    """Shardings for pipeline-layout params.

    stages/* leaves: (K, lps, ...) -> P('model', None, fsdp...).
    everything else (embed/head/prefix/shared_block/...): fsdp over data,
    last dim over model when divisible.  FSDP uses the intra-pod 'data'
    axis only — params replicate across pods (the pod axis is pure DP,
    which is where the paper's DP gradient compression applies).
    """
    dsize = mesh.shape["data"]

    def stage_rule(leaf):
        spec = [None] * leaf.ndim
        spec[0] = "model"
        fd = _stage_fsdp_dim(leaf, dsize)
        if fd is not None:
            spec[fd] = "data"
        return NamedSharding(mesh, P(*spec))

    def other_rule(leaf):
        spec = [None] * leaf.ndim
        fd = fsdp_dim(leaf.shape, dsize, 0)
        if fd is not None:
            spec[fd] = "data"
        msz = mesh.shape["model"]
        if leaf.ndim >= 2 and spec[-1] is None and \
                leaf.shape[-1] % msz == 0 and fd != leaf.ndim - 1:
            spec[-1] = "model"
        return NamedSharding(mesh, P(*spec))

    out = {}
    for k, v in params_shape.items():
        out[k] = jax.tree.map(stage_rule if k == "stages" else other_rule, v)
    return out


def _is_expert_leaf(leaf, stage_leaf: bool) -> bool:
    """MoE expert stacks are the only 5-D stage leaves (K, lps, E, d, ff).
    They get skip=3 (never shard the expert dim in the baseline) and are
    gathered per-expert inside the MoE scan, not per-layer."""
    return stage_leaf and leaf.ndim >= 5


def _stage_fsdp_dim(leaf, dsize: int):
    return fsdp_dim(leaf.shape, dsize, 3 if _is_expert_leaf(leaf, True)
                    else 2)


def fsdp_dims_tree(tree_shape, dsize: int, skip: int, shift: int = 0,
                   stage: bool = False):
    """Static pytree of Optional[int]: which dim of each leaf is
    FSDP-sharded over `data` (computed on GLOBAL shapes; `shift` adjusts
    indices for dims squeezed/scanned away inside shard_map).  Expert
    leaves are marked -1 here (gathered per-expert, see expert_axes)."""
    def rule(leaf):
        if _is_expert_leaf(leaf, stage):
            return -1
        fd = fsdp_dim(leaf.shape, dsize, skip)
        return -1 if fd is None else fd - shift
    return jax.tree.map(rule, tree_shape)


def expert_axes(stages_shape, dsize: int) -> dict:
    """{leaf name: gather axis of a single expert's weight inside the
    MoE expert scan} for the 5-D expert leaves.  Global (K, lps, E, d,
    ff) with fsdp dim fd -> per-expert local axis fd - 3."""
    axes = {}
    ffn = stages_shape.get("ffn", {}) if isinstance(stages_shape, dict) \
        else {}
    for name in ("w_gate", "w_up", "w_down"):
        leaf = ffn.get(name)
        if leaf is not None and leaf.ndim >= 5:
            fd = _stage_fsdp_dim(leaf, dsize)
            axes[name] = -1 if fd is None else fd - 3
    return axes


def gather_fsdp(tree, dims_tree):
    """Per-leaf all-gather over 'data' at the recorded dim (ZeRO-3)."""
    def g(leaf, fd):
        if fd < 0:
            return leaf
        return jax.lax.all_gather(leaf, "data", axis=fd, tiled=True)
    return jax.tree.map(g, tree, dims_tree)


# ---------------------------------------------------------------------------
# boundary transfer (compressed ppermute with custom_vjp)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def make_transfer(mode: str, fw_bits: int, bw_bits: int, stochastic: bool,
                  num_stages: int, axis: str = "model",
                  backend: str = "reference"):
    """Returns transfer(out, m_out_s, m_in_s, key) ->
    (recv, new_m_out_s, new_m_in_s); all (mb, S, d) floats.

    mode: 'fp32' | 'warmup' | 'directq' | 'aqsgd'.  backend selects the
    boundary codec (`repro.core.boundary`): the ppermute ships exactly
    the packed uint8 codes + f32 scales the fused kernel emits — nothing
    is re-packed on the wire path."""
    if mode in ("directq", "aqsgd"):
        # the real wire requires dense byte-aligned packing; fw3/bw6
        # ablation widths are simulation-only (training/simulated.py)
        assert fw_bits in B.PACKABLE_BITS, \
            f"wire fw_bits must be one of {B.PACKABLE_BITS}, got {fw_bits}"
        assert bw_bits >= 32 or bw_bits in B.PACKABLE_BITS, \
            f"wire bw_bits must be one of {B.PACKABLE_BITS}, got {bw_bits}"
    fwd_perm = tuple((i, (i + 1) % num_stages) for i in range(num_stages))
    bwd_perm = tuple((j, i) for i, j in fwd_perm)

    def pp(x, perm):
        return jax.lax.ppermute(x, axis, perm)

    def _fwd(out, m_out_s, m_in_s, key):
        d = out.shape[-1]
        if mode in ("fp32", "warmup"):
            recv = pp(out, fwd_perm)
            if mode == "warmup":
                new_m_out, new_m_in = out, recv
            else:
                new_m_out, new_m_in = m_out_s, m_in_s
        elif mode == "directq":
            packed, scale = B.encode(out, bits=fw_bits,
                                     stochastic=stochastic, key=key,
                                     backend=backend)
            packed, scale = pp(packed, fwd_perm), pp(scale, fwd_perm)
            recv = B.decode(packed, scale, bits=fw_bits, d=d,
                            dtype=out.dtype, backend=backend)
            new_m_out, new_m_in = m_out_s, m_in_s
        elif mode == "aqsgd":
            packed, scale, nmo = B.encode_delta(
                out, m_out_s, bits=fw_bits, stochastic=stochastic,
                key=key, backend=backend)
            new_m_out = nmo.astype(m_out_s.dtype)
            packed, scale = pp(packed, fwd_perm), pp(scale, fwd_perm)
            new_m_in = B.decode_accumulate(
                packed, scale, m_in_s, bits=fw_bits,
                backend=backend).astype(m_in_s.dtype)
            recv = new_m_in.astype(out.dtype)
        else:
            raise ValueError(mode)
        return recv, new_m_out, new_m_in

    @jax.custom_vjp
    def transfer(out, m_out_s, m_in_s, key):
        return _fwd(out, m_out_s, m_in_s, key)

    def transfer_fwd(out, m_out_s, m_in_s, key):
        outs = _fwd(out, m_out_s, m_in_s, key)
        zeros = (jnp.zeros((), m_out_s.dtype), jnp.zeros((), m_in_s.dtype))
        return outs, (key, zeros)

    def transfer_bwd(res, gs):
        key, (zo, zi) = res
        mo_dt, mi_dt = zo.dtype, zi.dtype
        g = gs[0]                      # buffer cotangents are discarded:
        d = g.shape[-1]                # messages are not differentiated
        if mode in ("fp32", "warmup") or bw_bits >= 32:
            gout = pp(g, bwd_perm)
        else:
            kb = jax.random.fold_in(key, 7)
            packed, scale = B.encode(g, bits=bw_bits,
                                     stochastic=stochastic, key=kb,
                                     backend=backend)
            packed, scale = pp(packed, bwd_perm), pp(scale, bwd_perm)
            gout = B.decode(packed, scale, bits=bw_bits, d=d,
                            dtype=g.dtype, backend=backend)
        zero = np.zeros(key.shape, jax.dtypes.float0)
        return (gout, jnp.zeros(g.shape, mo_dt), jnp.zeros(g.shape, mi_dt),
                zero)

    transfer.defvjp(transfer_fwd, transfer_bwd)
    return transfer


# ---------------------------------------------------------------------------
# DP gradient wire (error-feedback compressed allreduce, paper Fig. 5)
# ---------------------------------------------------------------------------

def replicate_leaves(mesh, tree):
    """Pin every leaf of `tree` to a fully-replicated sharding.

    GSPMD workaround (jax 0.4.x, meshes with a model axis):
    ``jnp.concatenate`` of differently-sharded flattened leaves — the
    exact shape of `grad_compress.flatten_bucket` on the gradient or
    parameter tree — miscompiles and DOUBLES the values of multi-axis
    sharded leaves (the partitioner treats the replicas it gathers as
    partial sums).  Constraining each leaf replicated before the
    reshape+concat forces a plain all-gather first, which is what the
    wire's P(None, None) bucket input needs anyway.  The ring-sharded
    loss-parity worker (tests/workers/pipeline_worker.py
    ``check_dp_wire_parity``) regresses this: without the constraint
    the DP bucket ships 2x gradients on any mesh with model > 1."""
    def rep(leaf):
        spec = P(*([None] * leaf.ndim))
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec))
    return jax.tree.map(rep, tree)


def make_dp_grad_wire(mesh, comm: CommConfig):
    """shard_map'd compressed gradient allreduce over the DP axes.

    The gradient tree is flattened into one (rows, group_d) bucket
    (`core.grad_compress.bucket_layout`) which every device holds in
    full.  ``comm.dp.wire`` names the collective in the wire registry
    (`repro.comm.wires` — ``--list-wires`` prints the table); any
    registered full-mean DP wire flows through here with NO trainer
    changes — that is the point of the registry (the ``fp16``
    passthrough is the in-tree example).  The built-in codec wires
    (``ring``/``psum``) pmax-share the rowwise scale, quantize through
    the fused boundary codec, and accumulate int32 codes, so they
    produce BIT-IDENTICAL results and the switch is purely a wire-cost
    choice (see each `core.collectives` docstring).

    Error-feedback state is per DP rank: a (D, rows, group_d) array
    sharded over the data axes so each device carries exactly its own
    feedback bucket (``comm.dp.error_feedback=False`` zeroes the carry
    — plain one-shot quantization; the state slot stays for layout
    stability).

    Noise keys fold in the device's DP position, so ranks draw
    independent rounding noise and the allreduce is a genuine n-worker
    compressed mean — bit-identical to the wire's registered simulator
    (`WireSpec.sim_allreduce`) with the same base key and the same
    per-rank inputs, where the wire claims bit parity at all.  (In
    `make_train_step` the input bucket is the pjit-level gradient,
    already reduced over data by autodiff — see the module docstring's
    placement caveat.)"""
    daxes = data_axes(mesh)
    axis = daxes if len(daxes) > 1 else daxes[0]
    dpc = comm.dp
    # sharded wires have no standalone mean-producing form at this
    # level: their segment mean must stay inside the shard_map that
    # consumes it (`make_dp_sharded_update`), so this factory only
    # serves the full-mean wires.
    spec = CW.get_wire(dpc.wire, plane="dp-grad")
    assert spec.collective is not None and not spec.sharded, dpc.wire
    # chunkable wires take the K-chunk double-buffered schedule knob;
    # CommConfig already validated chunks against the registry flag
    extra = {"chunks": dpc.chunks} if spec.chunkable else {}

    def wire(g2d, err, key):
        e = err[0] if dpc.error_feedback else jnp.zeros_like(err[0])
        mean, new_err = spec.collective(
            g2d, e, axis, dpc.bits, key,
            stochastic=dpc.stochastic, backend=dpc.backend, **extra)
        # payload guard (repro.comm.faults): NaN-poison a corrupt or
        # dropped-hop decoded mean; bit-exact passthrough when clean
        mean, new_err = CF.guard_dp_pair(mean, new_err)
        if not dpc.error_feedback:
            new_err = jnp.zeros_like(new_err)
        return mean, new_err[None]

    return shard_map(wire, mesh,
                     (P(None, None), P(axis, None, None), P()),
                     (P(None, None), P(axis, None, None)))


def make_dp_sharded_update(mesh, comm: CommConfig,
                           opt_cfg: adamw.AdamWConfig, glayout):
    """The fused ZeRO step for ``dp_wire="ring-sharded"``: compressed
    reduce-scatter + segment-owner AdamW + parameter all-gather, all
    inside ONE shard_map over the DP axes.

    Per DP rank: ship the packed b-bit codes of every segment to its
    owner (`C.ring_ef_reduce_scatter_bucket`), decode only the owned
    segment's mean, update the owned (seg, group_d) slices of the
    parameter bucket and the AdamW moments
    (`adamw.apply_bucket_updates` — moments never exist unsharded),
    then ``all_gather`` the UPDATED f32 parameter segments so every
    rank leaves with the full new bucket.  That gather is the ZeRO
    parameter all-gather that replaces the gradient all-gather — it is
    an explicit collective here (visible to `launch/hlo_cost`), and the
    full-bucket output is genuinely replicated on every device, so the
    pjit-level unflatten consumes a clean P(None, None) array exactly
    like the full ring's mean.  (Keeping the segment mean INSIDE the
    shard_map matters: handing a data-sharded, model-unmentioned wire
    output back to GSPMD for the optimizer arithmetic lets the
    partitioner introduce cross-model reductions of values it believes
    are partial — the bit-parity worker caught exactly that.)

    Returns update(bucket, dp_error, pbucket, mu, nu, step, key) ->
    (new full bucket (rows, group_d), new dp_error, new mu, new nu,
    new step); pbucket/mu/nu are (n_ranks, seg, group_d) stacks sharded
    one segment per rank.  The collective comes from the wire registry
    (``comm.dp.wire`` must name a ``sharded=True`` spec)."""
    daxes = data_axes(mesh)
    axis = daxes if len(daxes) > 1 else daxes[0]
    rows = glayout.rows
    dpc = comm.dp
    spec = CW.get_wire(dpc.wire, plane="dp-grad")
    assert spec.sharded and spec.collective is not None, dpc.wire
    extra = {"chunks": dpc.chunks} if spec.chunkable else {}

    def upd(g2d, err, pb, mu, nu, step, key):
        e = err[0] if dpc.error_feedback else jnp.zeros_like(err[0])
        seg_mean, new_err = spec.collective(
            g2d, e, axis, dpc.bits, key,
            stochastic=dpc.stochastic, backend=dpc.backend, **extra)
        # expect_nonzero off: a small model can leave this rank's
        # segment entirely padding rows (legitimately all-zero)
        seg_mean, new_err = CF.guard_dp_pair(seg_mean, new_err,
                                             expect_nonzero=False)
        if not dpc.error_feedback:
            new_err = jnp.zeros_like(new_err)
        new_pseg, new_opt = adamw.apply_bucket_updates(
            opt_cfg, pb[0], seg_mean,
            {"mu": mu[0], "nu": nu[0], "step": step})
        full = jax.lax.all_gather(new_pseg, axis, axis=0,
                                  tiled=True)[:rows]
        return (full, new_err[None], new_opt["mu"][None],
                new_opt["nu"][None], new_opt["step"])

    seg_spec = P(axis, None, None)
    return shard_map(upd, mesh,
                     (P(None, None), seg_spec, seg_spec, seg_spec,
                      seg_spec, P(), P()),
                     (P(None, None), seg_spec, seg_spec, seg_spec, P()))


def init_dp_error(pcfg: "PipelineConfig", params, n_ranks: int):
    """Initial per-rank error-feedback stack (n_ranks, rows, group_d) —
    the one place that ties the stack depth to the mesh's DP product and
    the bucket width to `pcfg.comm.dp.group_d`, so callers cannot drift
    from the layout `make_train_step` traces against.
    (`make_state_structs` derives its dp_error struct by eval_shape of
    THIS function, and tests/test_grad_compress.py pins the layout on
    every mesh the workers exercise.)

    The error stays full-bucket per rank under EVERY wire, including
    ``ring-sharded``: each rank encodes its whole compensated bucket
    (it ships every segment to that segment's owner), so only the
    *reduced gradient* and the optimizer state are segment-sharded."""
    err = GC.init_error_state(params, pcfg.comm.dp_group_d)
    return jnp.stack([err] * n_ranks)


def dp_bucket_segment(pcfg: "PipelineConfig", params, n_ranks: int) -> int:
    """Segment rows of the ZeRO-sharded gradient bucket: the single
    source for the (n_ranks, seg, group_d) layout shared by the wire
    output, `adamw.init_bucket_opt_state`, and the pjit sharding
    specs."""
    lay = GC.bucket_layout(params, pcfg.comm.dp_group_d)
    return C.ring_segment_rows(lay.rows, n_ranks)


def init_sharded_opt(pcfg: "PipelineConfig", params, n_ranks: int) -> dict:
    """Segment-partitioned AdamW state for ``dp_wire="ring-sharded"``:
    (n_ranks, seg, group_d) moment buckets, one owned segment per DP
    rank (placed P(data-axes) by `make_train_step`'s state specs).
    Replaces `adamw.init_opt_state`'s per-leaf tree in sharded mode."""
    seg = dp_bucket_segment(pcfg, params, n_ranks)
    return adamw.init_bucket_opt_state(n_ranks, seg,
                                       pcfg.comm.dp_group_d)


# ---------------------------------------------------------------------------
# message-buffer codec (z-bit storage, paper §H.5)
# ---------------------------------------------------------------------------

def buffer_read(pcfg: PipelineConfig, buf, ids):
    """buf slice for a microbatch -> f32 (mb, S, d).

    Messages are never differentiated (the transfer custom_vjp discards
    their cotangents), so the codec runs under stop_gradient — which also
    keeps the fused pallas decode out of the autodiff trace."""
    zb = pcfg.comm.zbuf
    if zb.bits:
        codes = jax.lax.stop_gradient(buf["codes"][ids])
        scale = jax.lax.stop_gradient(buf["scale"][ids])
        d = buf["codes"].shape[-1] * Q.codes_per_byte(zb.bits)
        return zb.codec().decode(codes, scale, d=d)
    return buf[ids].astype(jnp.float32)


def buffer_write(pcfg: PipelineConfig, buf, ids, val, keep_mask):
    """Store new messages at ids (keep old rows where ~keep_mask)."""
    zb = pcfg.comm.zbuf
    if zb.bits:
        packed, scale = zb.codec().encode(jax.lax.stop_gradient(val))
        old_c, old_s = buf["codes"][ids], buf["scale"][ids]
        m = keep_mask[..., None, None]
        return {
            "codes": buf["codes"].at[ids].set(jnp.where(m, packed, old_c)),
            "scale": buf["scale"].at[ids].set(jnp.where(m, scale, old_s)),
        }
    old = buf[ids]
    m = keep_mask[..., None, None]
    return buf.at[ids].set(jnp.where(m, val.astype(buf.dtype), old))


def buffer_structs(pcfg: PipelineConfig, k: int, n: int, seq: int, d: int):
    """ShapeDtypeStructs for one buffer array (m_out or m_in)."""
    zbits = pcfg.comm.zbuf.bits
    if zbits:
        pw = Q.packed_width(d, zbits)
        return {"codes": jax.ShapeDtypeStruct((k, n, seq, pw), jnp.uint8),
                "scale": jax.ShapeDtypeStruct((k, n, seq, 1), jnp.float32)}
    return jax.ShapeDtypeStruct((k, n, seq, d),
                                jnp.dtype(pcfg.buffer_dtype))


# ---------------------------------------------------------------------------
# stage function: scan over this stage's (padded) layers
# ---------------------------------------------------------------------------

def _apply_layer(cfg: ModelConfig, lp, h, positions, window, extra,
                 block_k: int, expert_map=None, moe_ep=None):
    """One live trunk layer (family dispatch).  h: (mb, S, d)."""
    fam = cfg.family
    if fam in ("ssm", "hybrid"):
        return Mo._mamba_layer(cfg, lp, h)
    h, _, _ = Mo._attn_ffn_layer(cfg, lp, h, positions, window,
                                 block_k=block_k, expert_map=expert_map,
                                 moe_ep=moe_ep)
    if fam == "audio":                       # decoder cross-attention
        b, se, d = extra.shape
        hk, hd = cfg.num_kv_heads, cfg.head_dim
        dtype = h.dtype
        xk = (extra @ lp["xattn"]["wk"].astype(dtype)).reshape(
            b, se, hk, hd)
        xv = (extra @ lp["xattn"]["wv"].astype(dtype)).reshape(
            b, se, hk, hd)
        xa, _ = L.attention(
            lp["xattn"], L.rmsnorm(lp["norm_x"], h, cfg.norm_eps),
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            positions=positions, window=L.BIG_WINDOW, cross_kv=(xk, xv),
            block_k=block_k)
        h = h + xa
    return h


def make_stage_fn(cfg: ModelConfig, pcfg: PipelineConfig, lay: StageLayout,
                  layer_dims, shared_dims, exp_axes=None, ep_size: int = 0):
    """stage_fn(stage_params, flags, shared_full, h, positions, extra)."""
    if exp_axes:
        def expert_map(name, leaf, e):
            w = jax.lax.dynamic_index_in_dim(leaf, e, 0, keepdims=False)
            ax = exp_axes[name]
            if ax < 0:
                return w
            return jax.lax.all_gather(w, "data", axis=ax, tiled=True)
    else:
        expert_map = None
    if exp_axes and pcfg.moe_mode == "expert_parallel":
        def ep_weights(name, leaf):
            """FSDP-sharded expert weights -> full weights of MY experts.

            leaf: (E, ..., shard, ...) with dim (exp_axes[name]+1)
            sharded over `data`.  Device g needs experts
            [g·E/D, (g+1)·E/D) whose shards live on every device — each
            device ships its local shard of expert e_j to device j
            (weight all_to_all: 1/D the bytes of a zero3 all_gather)."""
            e = leaf.shape[0]
            ne = max(e // ep_size, 1)
            ax = exp_axes[name]
            idx = (jnp.arange(ep_size)[:, None] * e) // ep_size \
                + jnp.arange(ne)[None, :]
            send = leaf[idx]                    # (D, ne, *wdims_local)
            if ax < 0:                          # weight not sharded
                g = jax.lax.axis_index("data")
                return jax.lax.dynamic_index_in_dim(send, g, 0,
                                                    keepdims=False)
            recv = jax.lax.all_to_all(send, "data", split_axis=0,
                                      concat_axis=0, tiled=False)
            out = jnp.moveaxis(recv, 0, 1 + ax)  # D next to sharded dim
            s = out.shape
            return out.reshape(*s[:1 + ax], s[1 + ax] * s[2 + ax],
                               *s[3 + ax:])
        moe_ep = ("data", ep_size, ep_weights)
    else:
        moe_ep = None

    def body(carry, xs):
        h, positions, extra, shared_full = carry
        lp_sh, window, live, shared = xs
        lp = gather_fsdp(lp_sh, layer_dims)

        def live_fn(hh):
            return _apply_layer(cfg, lp, hh, positions, window, extra,
                                pcfg.block_k, expert_map, moe_ep)

        h = jax.lax.cond(live, live_fn, lambda hh: hh, h)
        if lay.shared_attn:
            def shared_fn(hh):
                out, _, _ = Mo._attn_ffn_layer(
                    cfg, shared_full, hh, positions,
                    cfg.sliding_window or hh.shape[1],
                    block_k=pcfg.block_k)
                return out
            h = jax.lax.cond(shared, shared_fn, lambda hh: hh, h)
        return (h, positions, extra, shared_full), None

    def stage_fn(stage_params, flags, shared_sh, h, positions, extra):
        windows, live, shared = flags
        shared_full = gather_fsdp(shared_sh, shared_dims) \
            if lay.shared_attn else shared_sh

        body_ = jax.checkpoint(body) if pcfg.remat else body

        def run(h):
            (h, _, _, _), _ = jax.lax.scan(
                body_, (h, positions, extra, shared_full),
                (stage_params, windows, live, shared))
            return h

        # nested: one checkpoint around the whole stage per tick (backward
        # re-runs the stage forward, re-gathering ZeRO-3 weights; only the
        # stage input is stored — GPipe's standard memory shape) on top of
        # the per-layer checkpoint.  layer: per-layer only (one less
        # recompute, more residency).
        if pcfg.remat and pcfg.remat_mode == "nested":
            return jax.checkpoint(run)(h)
        return run(h)

    return stage_fn


# ---------------------------------------------------------------------------
# pipeline trunk (runs inside shard_map)
# ---------------------------------------------------------------------------

def make_pipeline_fn(cfg: ModelConfig, pcfg: PipelineConfig,
                     lay: StageLayout, layer_dims, shared_dims,
                     exp_axes=None, ep_size: int = 0):
    K = lay.num_stages
    cc = pcfg.comm.activation
    mode = "warmup" if (pcfg.warmup and cc.mode == "aqsgd") else cc.mode
    has_bufs = cc.mode == "aqsgd"
    transfer = make_transfer(mode, cc.fw_bits, cc.bw_bits, cc.stochastic, K,
                             backend=B.resolve_backend(cc.backend))
    stage_fn = make_stage_fn(cfg, pcfg, lay, layer_dims, shared_dims,
                             exp_axes, ep_size)

    def pipeline_fn(stage_params, flags, shared_sh, h_all, extra_all, ids,
                    m_out, m_in, key):
        # strip the stage dim that shard_map left as size-1
        stage_params = jax.tree.map(lambda a: a[0], stage_params)
        flags = jax.tree.map(lambda a: a[0], flags)
        if has_bufs:
            m_out = jax.tree.map(lambda a: a[0], m_out)
            m_in = jax.tree.map(lambda a: a[0], m_in)
        k = jax.lax.axis_index("model")
        key = jax.random.fold_in(key, k)
        M, mb, seq, d = h_all.shape
        T = M + K - 1
        positions = jnp.broadcast_to(
            jnp.arange(seq, dtype=jnp.int32), (mb, seq))

        def _read_slices(mo, mi, j):
            """Pre-read the buffer slices tick ``j + k`` consumes: the
            send-side messages of microbatch clip(j) and the recv-side
            messages of microbatch clip(j+1) (the same clip the tick
            itself applies, so the last pre-read is in range even when
            it goes unused)."""
            jp = jnp.clip(j, 0, M - 1)
            jr = jnp.clip(j + 1, 0, M - 1)
            ids_s = jax.lax.dynamic_index_in_dim(ids, jp, 0,
                                                 keepdims=False)
            ids_r = jax.lax.dynamic_index_in_dim(ids, jr, 0,
                                                 keepdims=False)
            return (buffer_read(pcfg, mo, ids_s),
                    buffer_read(pcfg, mi, ids_r))

        def tick(carry, t):
            # buffered modes carry (mo_s, mi_s) — THIS tick's buffer
            # slices, pre-read at the END of the previous tick (after
            # its writes, so the values are identical to an in-tick
            # read).  The transfer's buffer operands are then ready
            # before the stage compute finishes: the next-tick message
            # decode and the activation ppermute overlap the compute
            # instead of serializing after it.  Bit-exact — a pure
            # scheduling change, gated by the pipeline_worker parity
            # suites.
            if has_bufs:
                state_in, outputs, mo, mi, mo_s, mi_s = carry
            else:
                state_in, outputs, mo, mi = carry
            j = t - k
            valid_p = (j >= 0) & (j < M)
            jp = jnp.clip(j, 0, M - 1)
            inp = jnp.where(
                k == 0,
                jax.lax.dynamic_index_in_dim(
                    h_all, jnp.clip(t, 0, M - 1), 0, keepdims=False),
                state_in)
            extra = None if extra_all is None else \
                jax.lax.dynamic_index_in_dim(extra_all, jp, 0,
                                             keepdims=False)
            out = stage_fn(stage_params, flags, shared_sh, inp, positions,
                           extra)
            prev = jax.lax.dynamic_index_in_dim(outputs, jp, 0,
                                                keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(valid_p, out, prev), jp, 0)

            ids_s = jax.lax.dynamic_index_in_dim(ids, jp, 0, keepdims=False)
            jr = jnp.clip(j + 1, 0, M - 1)
            valid_r = (j + 1 >= 0) & (j + 1 < M)
            ids_r = jax.lax.dynamic_index_in_dim(ids, jr, 0, keepdims=False)
            if not has_bufs:
                mo_s = mi_s = jnp.zeros_like(out, jnp.float32)
            recv, nmo, nmi = transfer(out, mo_s, mi_s,
                                      jax.random.fold_in(key, t))
            if has_bufs:
                mo = buffer_write(pcfg, mo, ids_s, nmo,
                                  valid_p & (k < K - 1))
                mi = buffer_write(pcfg, mi, ids_r, nmi,
                                  valid_r & (k > 0))
                mo_sn, mi_sn = _read_slices(mo, mi, j + 1)
                return (recv, outputs, mo, mi, mo_sn, mi_sn), None
            return (recv, outputs, mo, mi), None

        outputs0 = jnp.zeros((M, mb, seq, d), h_all.dtype)
        state0 = jnp.zeros((mb, seq, d), h_all.dtype)
        if has_bufs:
            mo_s0, mi_s0 = _read_slices(m_out, m_in, 0 - k)
            (_, outputs, mo, mi, _, _), _ = jax.lax.scan(
                tick, (state0, outputs0, m_out, m_in, mo_s0, mi_s0),
                jnp.arange(T, dtype=jnp.int32))
        else:
            (_, outputs, mo, mi), _ = jax.lax.scan(
                tick, (state0, outputs0, m_out, m_in),
                jnp.arange(T, dtype=jnp.int32))
        if has_bufs:
            restage = lambda a: a[None]
            return (outputs[None], jax.tree.map(restage, mo),
                    jax.tree.map(restage, mi))
        return outputs[None], m_out, m_in

    return pipeline_fn


# ---------------------------------------------------------------------------
# full train step (pjit embed/head/optimizer around the shard_map trunk)
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, pcfg: PipelineConfig, mesh,
                    opt_cfg: adamw.AdamWConfig, *, global_batch: int,
                    seq_len: int, buffer_samples: int):
    """Build the jitted pipeline train step + its sharding specs.

    Returns (train_step, specs) where specs describe the expected state
    pytree shardings (used both to place real arrays and to build
    ShapeDtypeStructs in the dry-run).
    """
    K = mesh.shape["model"]
    daxes = data_axes(mesh)
    D = int(np.prod([mesh.shape[a] for a in daxes]))   # batch replicas
    Df = mesh.shape["data"]                            # FSDP shards
    d_ax = daxes if len(daxes) > 1 else daxes[0]
    M = pcfg.microbatches
    assert global_batch % (D * M) == 0, (global_batch, D, M)
    lay = stage_layout(cfg, K)
    comm = pcfg.comm
    has_bufs = comm.mode == "aqsgd"
    trunk_seq = seq_len        # total trunk sequence (patches + text)

    # static per-leaf FSDP dims (global shapes -> in-scan local dims)
    params_shape = jax.eval_shape(
        lambda: to_pipeline_params(
            cfg, Mo.init_params(cfg, jax.random.PRNGKey(0)), K))
    layer_dims = fsdp_dims_tree(params_shape["stages"], Df, 2, shift=2,
                                stage=True)
    shared_shape = params_shape.get("shared_block", {})
    shared_dims = fsdp_dims_tree(shared_shape, Df, 0, shift=0)
    exp_axes = expert_axes(params_shape["stages"], Df) if cfg.has_moe \
        else None

    pipeline_fn = make_pipeline_fn(cfg, pcfg, lay, layer_dims, shared_dims,
                                   exp_axes, Df)
    flags = layer_flags(cfg, lay, trunk_seq)
    dp_bits = comm.dp.bits
    dp_sharded = bool(dp_bits) and comm.dp_wire_spec.sharded
    if dp_bits:
        glayout = GC.bucket_layout(params_shape, comm.dp_group_d)
        dp_seg = C.ring_segment_rows(glayout.rows, D)
        if dp_sharded:
            dp_update = make_dp_sharded_update(mesh, comm, opt_cfg,
                                               glayout)
        else:
            dp_wire = make_dp_grad_wire(mesh, comm)

    # ---- shard_map specs -------------------------------------------------
    def _stage_pspec(leaf):
        spec = [None] * leaf.ndim
        spec[0] = "model"
        fd = _stage_fsdp_dim(leaf, Df)
        if fd is not None:
            spec[fd] = "data"
        return P(*spec)

    def _plain_pspec(leaf):
        spec = [None] * leaf.ndim
        fd = fsdp_dim(leaf.shape, Df, 0)
        if fd is not None:
            spec[fd] = "data"
        return P(*spec)

    stage_specs = jax.tree.map(_stage_pspec, params_shape["stages"])
    shared_specs = jax.tree.map(_plain_pspec, shared_shape)
    flag_specs = (P("model", None),) * 3
    h_spec = P(None, d_ax, None, None)
    _bp = P("model", d_ax, None, None)
    if not has_bufs:
        buf_spec = P(None)
    elif comm.zbuf.bits:
        buf_spec = {"codes": _bp, "scale": _bp}
    else:
        buf_spec = _bp
    extra_spec = P(None, d_ax, None, None) if cfg.family == "audio" \
        else P(None)
    in_specs = (stage_specs, flag_specs, shared_specs, h_spec, extra_spec,
                P(None, d_ax), buf_spec, buf_spec, P())
    out_specs = (P("model", None, d_ax, None, None), buf_spec, buf_spec)

    smap = shard_map(pipeline_fn, mesh, in_specs, out_specs)

    # ---- loss -------------------------------------------------------------
    def loss_from_hidden(params, h, targets, mask):
        def chunk_loss(args):
            hh, tt, mm = args
            logits = Mo.lm_logits(params, cfg, hh)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, tt[..., None], axis=-1)[..., 0]
            return jnp.sum((lse - gold) * mm), jnp.sum(mm)

        # chunk over the *sequence* dim (batch stays data-sharded so every
        # device participates in every chunk); h: (M, Bmb, S, d)
        seq = h.shape[2]
        n_chunk = 1
        for c in range(min(pcfg.loss_chunks, seq), 0, -1):
            if seq % c == 0:
                n_chunk = c
                break

        def split(x):
            x = x.reshape(*x.shape[:2], n_chunk, seq // n_chunk,
                          *x.shape[3:])
            return jnp.moveaxis(x, 2, 0)

        nll, cnt = jax.lax.map(jax.checkpoint(chunk_loss),
                               (split(h), split(targets), split(mask)))
        return jnp.sum(nll) / jnp.maximum(jnp.sum(cnt), 1.0)

    # ---- the step ----------------------------------------------------------
    # batch convention: every batch leaf is microbatch-major,
    # (M, D*mb, ...), so no cross-device resharding is ever needed between
    # the pjit embed/loss sections and the shard_map pipeline.
    def train_step(state, batch, key):
        params = state["params"]

        def loss_fn(params):
            tokens = batch["tokens"]              # (M, Bmb, n_text)
            h = Mo.embed_tokens(params, cfg, tokens, batch.get("patches"))
            h = h.astype(cfg.jax_dtype)
            seq = h.shape[2]
            positions = jnp.broadcast_to(
                jnp.arange(seq, dtype=jnp.int32), h.shape[1:3])
            for i, lp in enumerate(params.get("prefix", [])):
                w = cfg.layer_window(i, seq)
                h = jax.vmap(lambda hh: Mo._attn_ffn_layer(
                    cfg, lp, hh, positions, w, block_k=pcfg.block_k)[0])(h)
            h_all = h
            ids = batch["sample_ids"]             # (M, Bmb)
            if cfg.family == "audio":
                enc = jax.vmap(lambda fr: Mo.encode_audio(
                    params, cfg, fr, remat=pcfg.remat,
                    block_k=pcfg.block_k))(batch["frames"])
                extra_all = enc.astype(cfg.jax_dtype)
            else:
                extra_all = jnp.zeros((M, 1, 1, 1), cfg.jax_dtype)
            shared = params.get("shared_block", {})
            if has_bufs:
                m_out, m_in = state["m_out"], state["m_in"]
            else:
                m_out = m_in = jnp.zeros((1,), cfg.jax_dtype)
            outputs, nmo, nmi = smap(
                params["stages"], flags, shared, h_all, extra_all, ids,
                m_out, m_in, key)
            h_out = outputs[K - 1]                # (M, Bmb, S, d)
            if cfg.num_patches:
                h_out = h_out[:, :, cfg.num_patches:]
            loss = loss_from_hidden(params, h_out, batch["targets"],
                                    batch["mask"])
            return loss, (nmo, nmi)

        (loss, (nmo, nmi)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        if dp_sharded:
            # ZeRO-sharded path: compressed reduce-scatter, segment-
            # owner AdamW, and the parameter all-gather all run inside
            # `make_dp_sharded_update`'s shard_map; only the (cheap)
            # flatten/unflatten between leaf layout and bucket layout
            # happens at the pjit level.
            bucket = GC.flatten_bucket(replicate_leaves(mesh, grads),
                                       glayout)
            pb = GC.flatten_bucket(replicate_leaves(mesh, params),
                                   glayout)
            pad = dp_seg * D - glayout.rows
            if pad:
                pb = jnp.pad(pb, ((0, pad), (0, 0)))
            pb = pb.reshape(D, dp_seg, glayout.group_d)
            opt = state["opt"]
            new_pb, new_dp_err, new_mu, new_nu, new_step = dp_update(
                bucket, state["dp_error"], pb, opt["mu"], opt["nu"],
                opt["step"], jax.random.fold_in(key, 977))
            new_params = GC.unflatten_bucket(new_pb, glayout, params)
            new_state = {"params": new_params,
                         "opt": {"mu": new_mu, "nu": new_nu,
                                 "step": new_step},
                         "dp_error": new_dp_err}
        else:
            if dp_bits:
                bucket = GC.flatten_bucket(
                    replicate_leaves(mesh, grads), glayout)
                mean, new_dp_err = dp_wire(bucket, state["dp_error"],
                                           jax.random.fold_in(key, 977))
                grads = GC.unflatten_bucket(mean, glayout, grads)
            new_params, new_opt = adamw.apply_updates(
                opt_cfg, params, grads, state["opt"])
            new_state = {"params": new_params, "opt": new_opt}
            if dp_bits:
                new_state["dp_error"] = new_dp_err
        if has_bufs:
            new_state["m_out"] = nmo
            new_state["m_in"] = nmi
        return new_state, {"loss": loss}

    # ---- state / batch specs (pjit level) ----------------------------------
    pspecs = pipeline_param_specs(mesh, params_shape)
    if dp_sharded:
        # segment-partitioned bucket moments: one owned segment per DP
        # rank, the same placement pattern as dp_error
        seg_sh = NamedSharding(mesh, P(d_ax, None, None))
        opt_specs = {"mu": seg_sh, "nu": seg_sh,
                     "step": NamedSharding(mesh, P())}
    elif opt_cfg.state_bits:
        def qspec(ns):
            scale_spec = P(*ns.spec[:-1], None) if len(ns.spec) else P()
            return {"codes": ns, "scale": NamedSharding(mesh, scale_spec)}
        moment_specs = jax.tree.map(qspec, pspecs,
                                    is_leaf=lambda x: isinstance(
                                        x, NamedSharding))
    else:
        moment_specs = pspecs
    if not dp_sharded:
        opt_specs = {"mu": moment_specs, "nu": moment_specs,
                     "step": NamedSharding(mesh, P())}
    state_specs = {"params": pspecs, "opt": opt_specs}
    if dp_bits:
        state_specs["dp_error"] = NamedSharding(mesh, P(d_ax, None, None))
    if has_bufs:
        bspec = NamedSharding(mesh, P("model", d_ax, None, None))
        if comm.zbuf.bits:
            bspec = {"codes": bspec, "scale": bspec}
        state_specs["m_out"] = bspec
        state_specs["m_in"] = bspec
    batch_specs = {
        "tokens": NamedSharding(mesh, P(None, d_ax, None)),
        "targets": NamedSharding(mesh, P(None, d_ax, None)),
        "mask": NamedSharding(mesh, P(None, d_ax, None)),
        "sample_ids": NamedSharding(mesh, P(None, d_ax)),
    }
    if cfg.family == "vlm":
        batch_specs["patches"] = NamedSharding(
            mesh, P(None, d_ax, None, None))
    if cfg.family == "audio":
        batch_specs["frames"] = NamedSharding(
            mesh, P(None, d_ax, None, None))

    step = jax.jit(train_step,
                   in_shardings=(state_specs, batch_specs, None),
                   out_shardings=(state_specs, None),
                   donate_argnums=(0,))
    meta = {
        "state_specs": state_specs, "batch_specs": batch_specs,
        "layout": lay, "microbatch": global_batch // D // M, "m": M,
        "params_shape": params_shape, "trunk_seq": trunk_seq,
        "buffer_samples": buffer_samples,
    }
    return step, meta


def make_state_structs(cfg: ModelConfig, pcfg: PipelineConfig, meta,
                       mesh, *, global_batch: int, seq_len: int,
                       opt_state_bits: int = 0):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    dt = cfg.jax_dtype
    params = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dt), meta["params_shape"])
    daxes = data_axes(mesh)
    D = int(np.prod([mesh.shape[a] for a in daxes]))
    comm = pcfg.comm
    if comm.dp.bits and comm.dp_wire_spec.sharded:
        # segment-partitioned bucket moments (one segment per DP rank)
        opt = jax.eval_shape(lambda p: init_sharded_opt(pcfg, p, D),
                             meta["params_shape"])
    else:
        if opt_state_bits:
            def qstruct(s):
                return {"codes": jax.ShapeDtypeStruct(s.shape, jnp.uint8),
                        "scale": jax.ShapeDtypeStruct(
                            (*s.shape[:-1], 1), jnp.float32)}
            moments = jax.tree.map(qstruct, params)
        else:
            moments = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                params)
        opt = {"mu": moments, "nu": moments,
               "step": jax.ShapeDtypeStruct((), jnp.int32)}
    state = {"params": params, "opt": opt}
    if comm.dp.bits:
        # derived by eval_shape of the ONE init function so the struct
        # cannot drift from the layout `make_train_step` traces against
        # (tests/test_grad_compress.py pins this on the worker meshes)
        state["dp_error"] = jax.eval_shape(
            lambda p: init_dp_error(pcfg, p, D), meta["params_shape"])
    if comm.mode == "aqsgd":
        K = mesh.shape["model"]
        daxes = data_axes(mesh)
        D = int(np.prod([mesh.shape[a] for a in daxes]))
        n_loc = meta["buffer_samples"]
        state["m_out"] = buffer_structs(pcfg, K, D * n_loc,
                                        meta["trunk_seq"], cfg.d_model)
        state["m_in"] = buffer_structs(pcfg, K, D * n_loc,
                                       meta["trunk_seq"], cfg.d_model)
    n_text = seq_len - (cfg.num_patches or 0)
    m = meta["m"]
    bmb = global_batch // m
    batch = {
        "tokens": jax.ShapeDtypeStruct((m, bmb, n_text), jnp.int32),
        "targets": jax.ShapeDtypeStruct((m, bmb, n_text), jnp.int32),
        "mask": jax.ShapeDtypeStruct((m, bmb, n_text), jnp.float32),
        "sample_ids": jax.ShapeDtypeStruct((m, bmb), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct(
            (m, bmb, cfg.num_patches, cfg.d_model), dt)
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (m, bmb, cfg.encoder_seq, cfg.d_model), dt)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return state, batch, key
