"""End-to-end training driver: pre-train, then fine-tune a GPT-2-family
model under AQ-SGD with the full substrate stack — data pipeline with
sample identity, AdamW, K-stage pipeline cuts with message buffers,
checkpointing, and a wire-cost report.

Container note: this box is a single CPU core, so the default model is
~5M params; --dim 768 --layers 12 gives the ~100M-class configuration
the same driver trains on real hardware.

    PYTHONPATH=src python examples/finetune_aqsgd.py --steps 100
"""
import argparse
import os

import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.comm import CommConfig
from repro.configs.base import get_config
from repro.core.aqsgd import CompressionConfig, buffer_nbytes
from repro.core.quantization import wire_bytes
from repro.data.pipeline import Dataset, DatasetConfig
from repro.optim.adamw import AdamWConfig
from repro.training import simulated as sim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--pretrain-steps", type=int, default=80)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--fw-bits", type=int, default=3)
    ap.add_argument("--bw-bits", type=int, default=6)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--out", default="results/finetune_aqsgd.npz")
    args = ap.parse_args()

    cfg = get_config("gpt2-xl-paper", smoke=True).with_(
        num_layers=args.layers, d_model=args.dim,
        num_heads=max(args.dim // 64, 1),
        num_kv_heads=max(args.dim // 64, 1), head_dim=64,
        d_ff=args.dim * 4)
    n_params = cfg.params_count()
    print(f"model: {args.layers}L d={args.dim} -> {n_params/1e6:.1f}M "
          f"params, {args.stages} pipeline stages")

    data = Dataset(DatasetConfig(num_samples=64, seq_len=args.seq,
                                 vocab_size=cfg.vocab_size))
    print("phase 1: pre-training (fp32)...")
    tcfg = sim.SimTrainConfig(
        num_stages=1,
        comm=CommConfig.from_legacy(CompressionConfig(mode="fp32")),
        optimizer=AdamWConfig(lr=2e-3, warmup_steps=10,
                              schedule="constant"))
    state, losses = sim.train(cfg, tcfg, data,
                              num_steps=args.pretrain_steps,
                              batch_size=args.batch, log_every=20)

    print(f"phase 2: AQ-SGD fine-tuning "
          f"(fw{args.fw_bits} bw{args.bw_bits}, K={args.stages})...")
    cc = CompressionConfig(mode="aqsgd", fw_bits=args.fw_bits,
                           bw_bits=args.bw_bits)
    tcfg = sim.SimTrainConfig(
        num_stages=args.stages, comm=CommConfig.from_legacy(cc),
        optimizer=AdamWConfig(lr=3e-4, warmup_steps=5,
                              schedule="constant"))
    ft_data = Dataset(DatasetConfig(num_samples=48, seq_len=args.seq,
                                    vocab_size=cfg.vocab_size, seed=9))
    state, ft_losses = sim.train(cfg, tcfg, ft_data, num_steps=args.steps,
                                 batch_size=args.batch, log_every=20,
                                 initial_params=state["params"])
    print(f"fine-tune loss: {ft_losses[0]:.3f} -> "
          f"{np.mean(ft_losses[-8:]):.3f}")

    # wire + storage accounting (what a real deployment would see)
    act_shape = (args.batch * args.seq, cfg.d_model)
    raw = int(np.prod(act_shape)) * 4
    wire = wire_bytes(act_shape, args.fw_bits)
    buf = buffer_nbytes(cc, args.stages - 1, ft_data.num_samples,
                        args.seq, cfg.d_model)
    print(f"boundary wire: {raw/1e6:.2f} MB -> {wire/1e6:.2f} MB "
          f"({raw/wire:.1f}x compression) per batch per boundary")
    print(f"message buffers: {buf/1e6:.1f} MB total "
          f"({args.stages-1} boundaries x {ft_data.num_samples} samples)")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    ckpt.save(args.out, {"params": state["params"],
                         "buffers": state["buffers"]})
    print(f"checkpoint (params + AQ-SGD buffers) saved to {args.out}")


if __name__ == "__main__":
    main()
