"""Batched serving example: prefill a batch of prompts, then decode with
KV caches through the public serve path (the same code the decode_32k /
long_500k dry-run shapes lower at 256-chip scale), plus a continuous-
batching pass over mixed-length prompts.

Communication knobs are the one CommConfig surface shared with the
train/serve launchers: ``--kv-bits 8`` stores the demo caches as packed
codes + group scales, ``--comm-config`` accepts the full JSON.

Runs three model families to show the cache machinery: dense GQA
(gemma2), attention-free SSM (mamba2), hybrid (zamba2).

    PYTHONPATH=src python examples/serve_batched.py --tiny --kv-bits 8
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import config as comm_cli
from repro.configs.base import get_config
from repro.models import model as Mo
from repro.serving import ContinuousBatcher, KVCodec, quantize_caches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized run: one arch, short prompts")
    comm_cli.add_cli_args(ap)
    args = ap.parse_args()
    comm = comm_cli.from_args(args)
    kv_codec = KVCodec.from_comm(comm)
    kvc = kv_codec if kv_codec.bits else None
    print("comm:", comm.to_json())

    batch, prompt, gen = (2, 8, 4) if args.tiny else (4, 24, 12)
    archs = ("gemma2-9b",) if args.tiny \
        else ("gemma2-9b", "mamba2-1.3b", "zamba2-2.7b")

    for arch in archs:
        cfg = get_config(arch, smoke=True)
        params = Mo.init_params(cfg, jax.random.PRNGKey(0))
        caches = Mo.init_caches(cfg, batch, prompt + gen, jnp.float32)
        # hybrid keeps a raw cache (kv.bits>0 is dense-family only)
        quant = kvc if cfg.family != "hybrid" else None
        if quant is not None:
            caches = quantize_caches(cfg, caches, quant)
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (batch, prompt), 0, cfg.vocab_size)

        t0 = time.time()
        logits, caches = Mo.forward_with_caches(
            params, cfg, prompts, caches, logits_last_only=True,
            kv_codec=quant)
        step = jax.jit(lambda p, c, t, _cfg=cfg, _q=quant:
                       Mo.forward_with_caches(p, _cfg, t, c,
                                              logits_last_only=True,
                                              kv_codec=_q))
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        out = [tok]
        for _ in range(gen - 1):
            logits, caches = step(params, caches, tok)
            tok = jnp.argmax(logits[:, -1], -1)[:, None]
            out.append(tok)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        gen_toks = jnp.concatenate(out, axis=1)
        print(f"{arch:14s} [{cfg.family:6s}] prefill {batch}x{prompt} + "
              f"decode {gen}: {dt:.1f}s; sample: "
              f"{gen_toks[0][:8].tolist()}")

    # ---- continuous batching over mixed-length prompts ---------------------
    cfg = get_config("gemma2-9b", smoke=True)
    params = Mo.init_params(cfg, jax.random.PRNGKey(0))
    bat = ContinuousBatcher(params, cfg, num_slots=batch,
                            cache_len=prompt + gen, kv_codec=kvc)
    rng = np.random.default_rng(2)
    for _ in range(batch * 2):
        plen = int(rng.integers(2, prompt + 1))
        bat.submit(rng.integers(0, cfg.vocab_size, plen).tolist(),
                   max_new_tokens=gen)
    reqs = bat.run()
    assert all(r.state == "DONE" for r in reqs)
    lens = sorted({len(r.prompt) for r in reqs})
    print(f"continuous: {len(reqs)} mixed-length requests "
          f"(lens {lens}) over {batch} slots OK")
    print("serving path OK for attention, SSM and hybrid cache types")


if __name__ == "__main__":
    main()
