"""Batched serving example: prefill a batch of prompts, then decode with
KV caches through the public serve path (the same code the decode_32k /
long_500k dry-run shapes lower at 256-chip scale).

Runs three model families to show the cache machinery: dense GQA
(gemma2), attention-free SSM (mamba2), hybrid (zamba2).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import model as Mo

BATCH, PROMPT, GEN = 4, 24, 12

for arch in ("gemma2-9b", "mamba2-1.3b", "zamba2-2.7b"):
    cfg = get_config(arch, smoke=True)
    params = Mo.init_params(cfg, jax.random.PRNGKey(0))
    caches = Mo.init_caches(cfg, BATCH, PROMPT + GEN, jnp.float32)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (BATCH, PROMPT),
                                 0, cfg.vocab_size)

    t0 = time.time()
    logits, caches = Mo.forward_with_caches(params, cfg, prompts, caches,
                                            logits_last_only=True)
    step = jax.jit(lambda p, c, t: Mo.forward_with_caches(
        p, cfg, t, c, logits_last_only=True))
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    out = [tok]
    for _ in range(GEN - 1):
        logits, caches = step(params, caches, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"{arch:14s} [{cfg.family:6s}] prefill {BATCH}x{PROMPT} + "
          f"decode {GEN}: {dt:.1f}s; sample: {gen[0][:8].tolist()}")
print("serving path OK for attention, SSM and hybrid cache types")
