"""Split learning with AQ-SGD (paper §H.6).

A client holds the input layers (private data side), the server holds
the middle of the network, and the client holds the head (private labels
side) — the model is cut twice and BOTH cuts exchange compressed
activations/gradients over the slow client<->server link.  AQ-SGD keeps
2-bit uplink traffic trainable where DirectQ degrades.

    PYTHONPATH=src python examples/split_learning.py
"""
import numpy as np

from repro.comm import CommConfig
from repro.configs.base import get_config
from repro.core.aqsgd import CompressionConfig
from repro.core.quantization import wire_bytes
from repro.data.pipeline import Dataset, DatasetConfig
from repro.optim.adamw import AdamWConfig
from repro.training import simulated as sim

# 3 stages = client-bottom | server | client-top  (two cut layers)
cfg = get_config("gpt2-xl-paper", smoke=True).with_(num_layers=3)
data = Dataset(DatasetConfig(num_samples=32, seq_len=32, vocab_size=512,
                             seed=21))

base_tcfg = sim.SimTrainConfig(
    num_stages=1,
    comm=CommConfig.from_legacy(CompressionConfig(mode="fp32")),
    optimizer=AdamWConfig(lr=2e-3, warmup_steps=5, schedule="constant"))
base, _ = sim.train(cfg, base_tcfg, data, num_steps=60, batch_size=8)

print("split learning: client | server | client, 2-bit uplink, "
      "8-bit downlink")
final = {}
for mode in ("fp32", "aqsgd", "directq"):
    tcfg = sim.SimTrainConfig(
        num_stages=3,
        comm=CommConfig.from_legacy(
            CompressionConfig(mode=mode, fw_bits=2, bw_bits=8)),
        optimizer=AdamWConfig(lr=3e-4, warmup_steps=5,
                              schedule="constant"))
    _, losses = sim.train(cfg, tcfg, data, num_steps=40, batch_size=8,
                          initial_params=base["params"])
    final[mode] = float(np.mean(losses[-8:]))
    print(f"  [{mode:8s}] final loss {final[mode]:.4f}")

raw = 8 * 32 * cfg.d_model * 4
wire = wire_bytes((8 * 32, cfg.d_model), 2)
print(f"\nper-batch uplink: {raw/1e3:.0f} KB -> {wire/1e3:.0f} KB "
      f"({raw/wire:.0f}x less client bandwidth)")
assert final["aqsgd"] < final["directq"]
print("AQ-SGD holds model quality at federated-client bandwidths (§H.6)")
