"""Quickstart: fine-tune a small LM over a simulated slow network with
AQ-SGD activation compression (2-bit forward / 4-bit backward), and see
that it tracks uncompressed training where direct quantization does not.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.comm import CommConfig
from repro.configs.base import get_config
from repro.core.aqsgd import CompressionConfig
from repro.data.pipeline import Dataset, DatasetConfig
from repro.optim.adamw import AdamWConfig
from repro.training import simulated as sim

# a 4-layer GPT-2-family model, cut into 4 pipeline stages (3 boundaries)
cfg = get_config("gpt2-xl-paper", smoke=True).with_(num_layers=4)
data = Dataset(DatasetConfig(num_samples=32, seq_len=32, vocab_size=512))

print("pre-training a base model (fp32)...")
base_tcfg = sim.SimTrainConfig(
    num_stages=1,
    comm=CommConfig.from_legacy(CompressionConfig(mode="fp32")),
    optimizer=AdamWConfig(lr=2e-3, warmup_steps=5, schedule="constant"))
base_state, base_losses = sim.train(cfg, base_tcfg, data, num_steps=60,
                                    batch_size=8)
print(f"  base loss: {base_losses[0]:.2f} -> {np.mean(base_losses[-5:]):.2f}")

results = {}
for mode in ("fp32", "aqsgd", "directq"):
    tcfg = sim.SimTrainConfig(
        num_stages=4,
        comm=CommConfig.from_legacy(
            CompressionConfig(mode=mode, fw_bits=2, bw_bits=4)),
        optimizer=AdamWConfig(lr=3e-4, warmup_steps=5,
                              schedule="constant"))
    _, losses = sim.train(cfg, tcfg, data, num_steps=40, batch_size=8,
                          initial_params=base_state["params"])
    results[mode] = float(np.mean(losses[-8:]))
    print(f"fine-tune [{mode:8s}] fw2 bw4: final loss {results[mode]:.4f}")

print()
print(f"AQ-SGD gap to FP32:  {results['aqsgd'] - results['fp32']:+.4f}")
print(f"DirectQ gap to FP32: {results['directq'] - results['fp32']:+.4f}")
assert results["aqsgd"] < results["directq"], "paper claim violated?!"
print("AQ-SGD compresses the wire 16x (fp32 -> 2 bit) and still tracks "
      "FP32 - the paper's headline result.")
