"""CI fast-tier runner: timing artifact + one-retry flake detector.

Runs the fast tier (``-m "not slow"``) exactly as CI always has, plus:

* ``--durations=25`` timing output is teed to
  ``ci_fast_tier_durations.txt`` (uploaded as a workflow artifact, so
  slow-creep in the fast tier is visible across runs without rerunning
  anything locally);
* failures are retried ONCE, individually, and the job FAILS EITHER
  WAY — a rerun that diverges from the first run (pass on retry) is a
  flake, which is itself a bug in a suite whose whole value is
  bit-parity gating, so it is reported loudly (``FLAKE DETECTED``)
  instead of being retried into silence; a rerun that fails again is a
  genuine failure and reports as such.

The failed-test list comes from the junit XML report (CI disables the
pytest cache with ``-p no:cacheprovider``, so ``--last-failed`` is not
available — the XML is also uploaded, giving the artifact a
machine-readable test list).

Usage: ``python tools/ci_fast_tier.py [extra pytest args...]``
Exit status: 0 iff the first full run passes.
"""
from __future__ import annotations

import subprocess
import sys
import xml.etree.ElementTree as ET

DURATIONS_PATH = "ci_fast_tier_durations.txt"
JUNIT_PATH = "ci_fast_tier_junit.xml"


def run_fast_tier(extra: list[str]) -> int:
    """One full fast-tier run with timing + junit artifacts; returns
    the pytest exit code (stdout is streamed AND teed to the timing
    artifact)."""
    cmd = [sys.executable, "-m", "pytest", "-q", "-m", "not slow",
           "-p", "no:cacheprovider", "--durations=25",
           f"--junitxml={JUNIT_PATH}",
           # xunit1 records each testcase's file= path — the reliable
           # node-id source (xunit2's classname mangles directories)
           "-o", "junit_family=xunit1"] + extra
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    with open(DURATIONS_PATH, "w") as tee:
        for line in proc.stdout:
            sys.stdout.write(line)
            tee.write(line)
    return proc.wait()


def failed_node_ids(junit_path: str = JUNIT_PATH) -> list[str]:
    """Node ids of failed/errored tests from the junit XML report (the
    cacheprovider is disabled in CI, so --last-failed cannot supply
    this list)."""
    try:
        root = ET.parse(junit_path).getroot()
    except (ET.ParseError, FileNotFoundError):
        return []
    ids = []
    for case in root.iter("testcase"):
        if case.find("failure") is not None \
                or case.find("error") is not None:
            path = case.get("file", "")
            if not path:
                cls = case.get("classname", "")
                path = cls.replace(".", "/") + ".py" if cls else ""
            name = case.get("name")
            ids.append(f"{path}::{name}" if path else name)
    return ids


def retry_once(node_ids: list[str]) -> int:
    """Rerun the failed tests once; returns the rerun's exit code."""
    cmd = [sys.executable, "-m", "pytest", "-q",
           "-p", "no:cacheprovider"] + node_ids
    return subprocess.call(cmd)


def main() -> int:
    rc = run_fast_tier(sys.argv[1:])
    if rc == 0:
        return 0
    failed = failed_node_ids()
    if not failed:
        # collection error or crash before any report — nothing to
        # retry, the first run's status stands
        print(f"ci_fast_tier: run failed (rc={rc}) with no junit "
              f"failure records; not retrying")
        return rc
    print(f"ci_fast_tier: {len(failed)} failure(s); retrying once to "
          f"classify genuine-vs-flake: {' '.join(failed)}")
    rerun_rc = retry_once(failed)
    if rerun_rc == 0:
        print("ci_fast_tier: FLAKE DETECTED — the failing tests "
              "passed on an identical rerun.  A parity suite that "
              "flakes is broken; failing the job.")
    else:
        print("ci_fast_tier: failures reproduced on rerun (genuine).")
    return rc


if __name__ == "__main__":
    sys.exit(main())
