"""Docs gate: markdown link integrity + docstring coverage.

Run as ``python tools/check_docs.py`` from the repo root (CI runs it in
the lint job; tests/test_docs.py keeps it green in-container).

Checks
------
1. Every RELATIVE markdown link in README.md, ROADMAP.md and docs/*.md
   resolves to an existing file (anchors and external URLs are not
   followed; badge/action links like ``../../actions/...`` that point
   outside the repo are skipped).
2. Every PUBLIC module-level function and class in ``src/repro/core``,
   ``src/repro/kernels``, ``src/repro/comm``, ``src/repro/serving``,
   ``src/repro/checkpoint`` and ``src/repro/analysis`` carries a
   docstring, and so does every module itself.  "Public" = name not
   starting with ``_``.
3. Every ``REPRO_*`` knob exported by ``src/repro/env.py`` (its
   ``KNOBS`` table, extracted statically — no imports) appears in the
   README env-var reference.

Code-level invariants (e.g. "nothing outside repro/env.py reads a
REPRO_* knob") live in `repro.analysis` lint rules, NOT here — the
regex scan this script used to run missed aliased imports
(``from os import environ as e``); the AST rule
``no-stray-env-read`` does not.

Every section runs to completion; problems print per-section and the
exit code is nonzero if ANY section found one.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
MD_FILES = [ROOT / "README.md", ROOT / "ROADMAP.md",
            *sorted((ROOT / "docs").glob("*.md"))]
PY_DIRS = [ROOT / "src" / "repro" / "core",
           ROOT / "src" / "repro" / "kernels",
           ROOT / "src" / "repro" / "comm",
           ROOT / "src" / "repro" / "serving",
           ROOT / "src" / "repro" / "checkpoint",
           ROOT / "src" / "repro" / "analysis",
           ROOT / "src" / "repro" / "analysis" / "rules"]
ENV_PY = ROOT / "src" / "repro" / "env.py"
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links() -> list[str]:
    """Relative markdown links must resolve from their file's dir."""
    errors = []
    for md in MD_FILES:
        if not md.exists():
            errors.append(f"{md.relative_to(ROOT)}: file missing")
            continue
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "#",
                                  "mailto:")):
                continue
            path = target.split("#")[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            try:
                resolved.relative_to(ROOT)
            except ValueError:
                continue          # points outside the repo (badges)
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    return errors


def check_docstrings() -> list[str]:
    """Public functions/classes/modules in the enrolled src/ packages
    must have docstrings."""
    errors = []
    for d in PY_DIRS:
        for py in sorted(d.glob("*.py")):
            tree = ast.parse(py.read_text())
            rel = py.relative_to(ROOT)
            if not ast.get_docstring(tree):
                errors.append(f"{rel}: missing module docstring")
            for node in tree.body:
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef,
                                         ast.ClassDef)):
                    continue
                if node.name.startswith("_"):
                    continue
                if not ast.get_docstring(node):
                    errors.append(f"{rel}:{node.lineno}: public "
                                  f"`{node.name}` has no docstring")
    return errors


def exported_knobs() -> list[str]:
    """The REPRO_* knob names in repro/env.py's KNOBS table, read
    statically (the lint job has no repro install)."""
    tree = ast.parse(ENV_PY.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "KNOBS"
                for t in node.targets):
            return [k.value for k in node.value.keys]
    raise ValueError(f"{ENV_PY.relative_to(ROOT)}: no KNOBS table "
                     f"found")


def check_env_knobs() -> list[str]:
    """Every exported REPRO_* knob must appear in the README env-var
    reference.  (Who may READ a knob is `repro.analysis`'s
    ``no-stray-env-read`` rule, not a docs concern.)"""
    errors = []
    readme = (ROOT / "README.md").read_text()
    try:
        knobs = exported_knobs()
    except ValueError as e:
        return [str(e)]
    for knob in knobs:
        if knob not in readme:
            errors.append(f"README.md: env knob `{knob}` exported by "
                          f"src/repro/env.py is not documented in the "
                          f"env-var reference")
    return errors


def main() -> int:
    """Run every section, print an aggregated per-section summary,
    exit nonzero if any section found a problem."""
    sections = (("links", check_links), ("docstrings", check_docstrings),
                ("env-knobs", check_env_knobs))
    total = 0
    for name, fn in sections:
        errors = fn()
        total += len(errors)
        for e in errors:
            print(f"DOCS-GATE [{name}] {e}")
        print(f"docs gate [{name}]: {len(errors)} problem(s)")
    print(f"docs gate: {total} problem(s)")
    return 1 if total else 0


if __name__ == "__main__":
    sys.exit(main())
