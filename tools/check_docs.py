"""Docs gate: markdown link integrity + docstring coverage.

Run as ``python tools/check_docs.py`` from the repo root (CI runs it in
the lint job; tests/test_docs.py keeps it green in-container).

Checks
------
1. Every RELATIVE markdown link in README.md, ROADMAP.md and docs/*.md
   resolves to an existing file (anchors and external URLs are not
   followed; badge/action links like ``../../actions/...`` that point
   outside the repo are skipped).
2. Every PUBLIC module-level function and class in ``src/repro/core``
   and ``src/repro/kernels`` carries a docstring, and so does every
   module itself.  "Public" = name not starting with ``_``.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
MD_FILES = [ROOT / "README.md", ROOT / "ROADMAP.md",
            *sorted((ROOT / "docs").glob("*.md"))]
PY_DIRS = [ROOT / "src" / "repro" / "core",
           ROOT / "src" / "repro" / "kernels"]
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links() -> list[str]:
    """Relative markdown links must resolve from their file's dir."""
    errors = []
    for md in MD_FILES:
        if not md.exists():
            errors.append(f"{md.relative_to(ROOT)}: file missing")
            continue
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "#",
                                  "mailto:")):
                continue
            path = target.split("#")[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            try:
                resolved.relative_to(ROOT)
            except ValueError:
                continue          # points outside the repo (badges)
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    return errors


def check_docstrings() -> list[str]:
    """Public functions/classes/modules in core/ and kernels/ must
    have docstrings."""
    errors = []
    for d in PY_DIRS:
        for py in sorted(d.glob("*.py")):
            tree = ast.parse(py.read_text())
            rel = py.relative_to(ROOT)
            if not ast.get_docstring(tree):
                errors.append(f"{rel}: missing module docstring")
            for node in tree.body:
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef,
                                         ast.ClassDef)):
                    continue
                if node.name.startswith("_"):
                    continue
                if not ast.get_docstring(node):
                    errors.append(f"{rel}:{node.lineno}: public "
                                  f"`{node.name}` has no docstring")
    return errors


def main() -> int:
    errors = check_links() + check_docstrings()
    for e in errors:
        print(f"DOCS-GATE {e}")
    print(f"docs gate: {len(errors)} problem(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
