"""Docs gate: markdown link integrity + docstring coverage.

Run as ``python tools/check_docs.py`` from the repo root (CI runs it in
the lint job; tests/test_docs.py keeps it green in-container).

Checks
------
1. Every RELATIVE markdown link in README.md, ROADMAP.md and docs/*.md
   resolves to an existing file (anchors and external URLs are not
   followed; badge/action links like ``../../actions/...`` that point
   outside the repo are skipped).
2. Every PUBLIC module-level function and class in ``src/repro/core``,
   ``src/repro/kernels``, ``src/repro/comm``, ``src/repro/serving``
   and ``src/repro/checkpoint`` carries a docstring, and so does every
   module itself.  "Public" = name not starting with ``_``.
3. Every ``REPRO_*`` knob exported by ``src/repro/env.py`` (its
   ``KNOBS`` table, extracted statically — no imports) appears in the
   README env-var reference, and no module outside ``repro/env.py``
   reads ``REPRO_*`` from ``os.environ`` directly.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
MD_FILES = [ROOT / "README.md", ROOT / "ROADMAP.md",
            *sorted((ROOT / "docs").glob("*.md"))]
PY_DIRS = [ROOT / "src" / "repro" / "core",
           ROOT / "src" / "repro" / "kernels",
           ROOT / "src" / "repro" / "comm",
           ROOT / "src" / "repro" / "serving",
           ROOT / "src" / "repro" / "checkpoint"]
ENV_PY = ROOT / "src" / "repro" / "env.py"
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
ENV_READ_RE = re.compile(
    r"(?:environ(?:\.get)?\s*[\[(]|getenv\s*\()\s*['\"]REPRO_")


def check_links() -> list[str]:
    """Relative markdown links must resolve from their file's dir."""
    errors = []
    for md in MD_FILES:
        if not md.exists():
            errors.append(f"{md.relative_to(ROOT)}: file missing")
            continue
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "#",
                                  "mailto:")):
                continue
            path = target.split("#")[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            try:
                resolved.relative_to(ROOT)
            except ValueError:
                continue          # points outside the repo (badges)
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    return errors


def check_docstrings() -> list[str]:
    """Public functions/classes/modules in core/ and kernels/ must
    have docstrings."""
    errors = []
    for d in PY_DIRS:
        for py in sorted(d.glob("*.py")):
            tree = ast.parse(py.read_text())
            rel = py.relative_to(ROOT)
            if not ast.get_docstring(tree):
                errors.append(f"{rel}: missing module docstring")
            for node in tree.body:
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef,
                                         ast.ClassDef)):
                    continue
                if node.name.startswith("_"):
                    continue
                if not ast.get_docstring(node):
                    errors.append(f"{rel}:{node.lineno}: public "
                                  f"`{node.name}` has no docstring")
    return errors


def exported_knobs() -> list[str]:
    """The REPRO_* knob names in repro/env.py's KNOBS table, read
    statically (the lint job has no repro install)."""
    tree = ast.parse(ENV_PY.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "KNOBS"
                for t in node.targets):
            return [k.value for k in node.value.keys]
    raise SystemExit(f"DOCS-GATE {ENV_PY}: no KNOBS table found")


def check_env_knobs() -> list[str]:
    """Every exported REPRO_* knob must appear in the README env-var
    reference, and nothing outside repro/env.py may read one from
    os.environ directly."""
    errors = []
    readme = (ROOT / "README.md").read_text()
    for knob in exported_knobs():
        if knob not in readme:
            errors.append(f"README.md: env knob `{knob}` exported by "
                          f"src/repro/env.py is not documented in the "
                          f"env-var reference")
    for py in sorted((ROOT / "src").rglob("*.py")):
        if py == ENV_PY:
            continue
        if ENV_READ_RE.search(py.read_text()):
            errors.append(f"{py.relative_to(ROOT)}: reads a REPRO_* "
                          f"knob from os.environ directly — route it "
                          f"through repro/env.py")
    return errors


def main() -> int:
    errors = check_links() + check_docstrings() + check_env_knobs()
    for e in errors:
        print(f"DOCS-GATE {e}")
    print(f"docs gate: {len(errors)} problem(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
